"""L1 correctness: the Pallas gradient kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and betas; fixed-case tests pin the exact
experiment shapes from DESIGN.md §5.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.psgld_grads import (
    MU_EPS,
    beta_divergence,
    pick_tile,
    psgld_grads,
    vmem_report,
)
from compile.kernels.ref import grads_ref

BETAS = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0]


def make_block(seed, m, n, k, beta):
    """Generate a (W, H, V) block with V drawn near the generative model
    so that mu is well-scaled for every beta (no pathological 1/mu^2)."""
    key = jax.random.PRNGKey(seed)
    kw, kh, kv = jax.random.split(key, 3)
    w = jax.random.uniform(kw, (m, k), minval=0.1, maxval=1.0)
    h = jax.random.uniform(kh, (k, n), minval=0.1, maxval=1.0)
    mu = w @ h
    if beta == 1.0:
        v = jax.random.poisson(kv, mu).astype(jnp.float32)
    else:
        v = mu * jax.random.uniform(kv, mu.shape, minval=0.5, maxval=1.5)
    return w, h, v.astype(jnp.float32)


def assert_matches_ref(w, h, v, beta, phi=1.0, rtol=2e-4, atol=2e-4):
    gw, gh, ll = psgld_grads(w, h, v, beta=beta, phi=phi)
    rgw, rgh, rll = grads_ref(w, h, v, beta=beta, phi=phi)
    np.testing.assert_allclose(gw, rgw, rtol=rtol, atol=atol)
    np.testing.assert_allclose(gh, rgh, rtol=rtol, atol=atol)
    np.testing.assert_allclose(ll, rll, rtol=rtol, atol=atol * 10)


@pytest.mark.parametrize("beta", BETAS)
def test_kernel_matches_ref_paper_block(beta):
    # the 32x32 block shape used by every part_update artifact
    w, h, v = make_block(0, 32, 32, 32, beta)
    assert_matches_ref(w, h, v, beta)


@pytest.mark.parametrize(
    "m,n,k",
    [(32, 32, 8), (32, 32, 16), (32, 32, 32), (32, 32, 50),
     (256, 256, 8), (256, 256, 32), (128, 96, 16)],
)
def test_kernel_matches_ref_experiment_shapes(m, n, k):
    w, h, v = make_block(1, m, n, k, 1.0)
    assert_matches_ref(w, h, v, 1.0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 40),
    beta=st.sampled_from(BETAS),
    phi=st.sampled_from([0.5, 1.0, 2.0]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_hypothesis(m, n, k, beta, phi, seed):
    w, h, v = make_block(seed, m, n, k, beta)
    assert_matches_ref(w, h, v, beta, phi)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), n=st.integers(1, 64), seed=st.integers(0, 99))
def test_kernel_tiling_invariance(m, n, seed):
    """The result must not depend on the chosen tile decomposition."""
    w, h, v = make_block(seed, m, n, 8, 1.0)
    full = psgld_grads(w, h, v, beta=1.0, bm=m, bn=n)
    tiled = psgld_grads(w, h, v, beta=1.0, bm=pick_tile(m, 16), bn=pick_tile(n, 16))
    for a, b in zip(full, tiled):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_kernel_negative_entries_sign_chain():
    """Pre-mirroring states can be negative; d/dw |w| = sign(w)."""
    w, h, v = make_block(3, 32, 32, 8, 1.0)
    w = w * jnp.where(jnp.arange(32)[:, None] % 2 == 0, -1.0, 1.0)
    h = h * jnp.where(jnp.arange(32)[None, :] % 3 == 0, -1.0, 1.0)
    assert_matches_ref(w, h, v, 1.0)
    # flipping the sign of W must flip the sign of G_W and leave G_H alone
    gw, gh, ll = psgld_grads(w, h, v, beta=1.0)
    gw2, gh2, ll2 = psgld_grads(-w, h, v, beta=1.0)
    np.testing.assert_allclose(gw2, -gw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gh2, gh, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ll2, ll, rtol=1e-5)


@pytest.mark.parametrize("beta", BETAS)
def test_gradient_matches_autodiff(beta):
    """G_W must equal the autodiff gradient of the summed loglik."""
    w, h, v = make_block(4, 32, 16, 8, beta)

    def ll(w_, h_):
        mu = jnp.abs(w_) @ jnp.abs(h_) + MU_EPS
        return -jnp.sum(beta_divergence(v, mu, beta))

    agw = jax.grad(ll, argnums=0)(w, h)
    agh = jax.grad(ll, argnums=1)(w, h)
    gw, gh, _ = psgld_grads(w, h, v, beta=beta)
    np.testing.assert_allclose(gw, agw, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(gh, agh, rtol=5e-3, atol=5e-3)


def test_zero_data_poisson():
    """v = 0 entries are legal for beta in [1, 2] (sparse data)."""
    w, h, _ = make_block(5, 32, 32, 8, 1.0)
    v = jnp.zeros((32, 32), jnp.float32)
    gw, gh, ll = psgld_grads(w, h, v, beta=1.0)
    assert np.all(np.isfinite(gw)) and np.all(np.isfinite(gh))
    assert np.isfinite(ll[0, 0])
    # with v=0 and KL, d = mu, so ll = -sum(mu)
    mu = jnp.abs(w) @ jnp.abs(h) + MU_EPS
    np.testing.assert_allclose(ll[0, 0], -jnp.sum(mu), rtol=1e-4)


def test_loglik_maximised_at_truth():
    """ll(mu*) >= ll(perturbed) for matched data (sanity of sign)."""
    w, h, v = make_block(6, 64, 64, 16, 2.0)
    v = w @ h  # noiseless
    _, _, ll_true = psgld_grads(w, h, v, beta=2.0)
    _, _, ll_pert = psgld_grads(w * 1.3, h, v, beta=2.0)
    assert ll_true[0, 0] > ll_pert[0, 0]


def test_vmem_report_fits():
    """The BlockSpec used by every artifact must fit VMEM comfortably."""
    for (m, n, k) in [(32, 32, 50), (128, 128, 64), (1024, 1024, 32)]:
        rep = vmem_report(m, n, k)
        assert rep["fits_16MiB"], rep
