"""L2 correctness: update-rule properties of the model functions that
get AOT-lowered (block_update / part_update / ld_update / monitors)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import block_update_ref, loglik_ref, rmse_ref

SEED = jnp.array([7, 42], dtype=jnp.uint32)


def make_state(seed, b, m, n, k):
    key = jax.random.PRNGKey(seed)
    kw, kh, kv = jax.random.split(key, 3)
    ws = jax.random.uniform(kw, (b, m, k), minval=0.1, maxval=1.0)
    hs = jax.random.uniform(kh, (b, k, n), minval=0.1, maxval=1.0)
    vs = jax.vmap(jnp.matmul)(ws, hs)
    return ws, hs, vs


def test_block_update_matches_ref():
    ws, hs, vs = make_state(0, 1, 32, 32, 8)
    w, h, v = ws[0], hs[0], vs[0]
    got_w, got_h = model.block_update(
        w, h, v, 0.01, 4.0, 1.0, 1.0, SEED, beta=1.0
    )
    ref_w, ref_h = block_update_ref(
        w, h, v, 0.01, 4.0, 1.0, 1.0, SEED, beta=1.0
    )
    np.testing.assert_allclose(got_w, ref_w, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_h, ref_h, rtol=1e-5, atol=1e-6)


def test_part_update_equals_per_block_updates():
    """vmap batching must be exactly the B independent block updates."""
    b = 4
    ws, hs, vs = make_state(1, b, 32, 32, 16)
    bw, bh = model.part_update(ws, hs, vs, 0.01, float(b), 1.0, 1.0, SEED,
                               beta=1.0)
    for i in range(b):
        seed_i = jax.random.fold_in(SEED, i)
        ew, eh = model.block_update(ws[i], hs[i], vs[i], 0.01, float(b),
                                    1.0, 1.0, seed_i, beta=1.0)
        np.testing.assert_allclose(bw[i], ew, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(bh[i], eh, rtol=1e-5, atol=1e-6)


def test_mirroring_nonnegative():
    ws, hs, vs = make_state(2, 2, 32, 32, 8)
    # large eps so the noise would certainly push entries negative
    bw, bh = model.part_update(ws, hs, vs, 0.5, 2.0, 1.0, 1.0, SEED,
                               beta=1.0, mirror=True)
    assert np.all(np.asarray(bw) >= 0)
    assert np.all(np.asarray(bh) >= 0)


def test_no_mirroring_goes_negative():
    ws, hs, vs = make_state(3, 2, 32, 32, 8)
    bw, bh = model.part_update(ws, hs, vs, 0.5, 2.0, 1.0, 1.0, SEED,
                               beta=2.0, mirror=False)
    assert np.any(np.asarray(bw) < 0) or np.any(np.asarray(bh) < 0)


def test_noise_variance_is_2eps():
    """With scale=0 and lam=0 the update is pure Langevin noise N(0,2eps)."""
    eps = 0.05
    w = jnp.full((64, 64), 5.0)
    h = jnp.full((64, 64), 5.0)
    v = jnp.abs(w) @ jnp.abs(h)
    draws = []
    for s in range(20):
        seed = jnp.array([s, 0], dtype=jnp.uint32)
        w2, _ = model.block_update(w, h, v, eps, 0.0, 0.0, 0.0, seed,
                                   beta=2.0, mirror=False)
        draws.append(np.asarray(w2 - w).ravel())
    noise = np.concatenate(draws)
    assert abs(noise.mean()) < 0.01
    np.testing.assert_allclose(noise.var(), 2 * eps, rtol=0.05)


def test_drift_is_linear_in_eps_grad():
    """update(seed) - pure_noise(seed) == eps * (scale*G_W - lam*sign(W))
    when mirroring is off (noise cancels at the same seed)."""
    ws, hs, vs = make_state(4, 1, 32, 32, 8)
    w, h, v = ws[0], hs[0], vs[0]
    eps, scale, lam = 0.01, 3.0, 0.7
    w_full, h_full = model.block_update(w, h, v, eps, scale, lam, lam, SEED,
                                        beta=1.0, mirror=False)
    w_noise, h_noise = model.block_update(w, h, v, eps, 0.0, 0.0, 0.0, SEED,
                                          beta=1.0, mirror=False)
    from compile.kernels.psgld_grads import psgld_grads

    gw, gh, _ = psgld_grads(w, h, v, beta=1.0)
    np.testing.assert_allclose(
        w_full - w_noise, eps * (scale * gw - lam * jnp.sign(w)),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        h_full - h_noise, eps * (scale * gh - lam * jnp.sign(h)),
        rtol=1e-4, atol=1e-5,
    )


def test_ld_update_is_scale_one_block_update():
    ws, hs, vs = make_state(5, 1, 64, 64, 8)
    w, h, v = ws[0], hs[0], vs[0]
    lw, lh = model.ld_update(w, h, v, 0.01, 1.0, 1.0, SEED, beta=1.0)
    bw, bh = model.block_update(w, h, v, 0.01, 1.0, 1.0, 1.0, SEED, beta=1.0)
    np.testing.assert_allclose(lw, bw, rtol=1e-6)
    np.testing.assert_allclose(lh, bh, rtol=1e-6)


@pytest.mark.parametrize("beta", [0.0, 0.5, 1.0, 2.0])
def test_loglik_monitor_matches_ref(beta):
    ws, hs, vs = make_state(6, 1, 64, 64, 16)
    w, h, v = ws[0], hs[0], vs[0]
    got = model.loglik(w, h, v, beta=beta)
    ref = loglik_ref(w, h, v, beta=beta)
    np.testing.assert_allclose(got, ref, rtol=1e-4)


def test_rmse_and_predict():
    ws, hs, vs = make_state(7, 1, 32, 32, 8)
    w, h, v = ws[0], hs[0], vs[0]
    np.testing.assert_allclose(model.rmse(w, h, v), rmse_ref(w, h, v),
                               rtol=1e-5)
    # exact factorisation reconstructs exactly
    assert float(model.rmse(w, h, jnp.abs(w) @ jnp.abs(h))) < 1e-5
    np.testing.assert_allclose(model.predict(w, h), jnp.abs(w) @ jnp.abs(h),
                               rtol=1e-6)


def test_log_posterior_includes_priors():
    ws, hs, vs = make_state(8, 1, 32, 32, 8)
    w, h, v = ws[0], hs[0], vs[0]
    ll = model.loglik(w, h, v, beta=1.0)
    lp = model.log_posterior(w, h, v, 2.0, 3.0, beta=1.0)
    expect = ll - 2.0 * jnp.sum(jnp.abs(w)) - 3.0 * jnp.sum(jnp.abs(h))
    np.testing.assert_allclose(lp, expect, rtol=1e-5)


def test_deterministic_given_seed():
    ws, hs, vs = make_state(9, 2, 32, 32, 8)
    a = model.part_update(ws, hs, vs, 0.01, 2.0, 1.0, 1.0, SEED, beta=1.0)
    b = model.part_update(ws, hs, vs, 0.01, 2.0, 1.0, 1.0, SEED, beta=1.0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c, _ = model.part_update(ws, hs, vs, 0.01, 2.0, 1.0, 1.0,
                             jnp.array([1, 1], jnp.uint32), beta=1.0)
    assert not np.allclose(a[0], c)
