"""AOT path sanity: lowering to HLO text, manifest schema, and numeric
agreement between a lowered+reparsed computation and the live function."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.aot import beta_tag, build_entries, spec, to_hlo_text


def test_beta_tag():
    assert beta_tag(1.0) == "b1p0"
    assert beta_tag(0.5) == "b0p5"
    assert beta_tag(-1.0) == "bm1p0"


def test_entries_unique_and_complete():
    entries = build_entries()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names))
    kinds = {e["kind"] for e in entries}
    assert kinds == {"part_update", "ld_update", "loglik"}
    # every experiment shape from DESIGN.md §5 is present
    for needed in [
        "part_update_b1p0_B8_m32_n32_k32",    # fig2a 256
        "part_update_b1p0_B32_m32_n32_k32",   # fig2a 1024
        "part_update_b0p5_B32_m32_n32_k32",   # fig2b
        "part_update_b1p0_B8_m32_n32_k8",     # fig3 audio
        "ld_update_b1p0_i1024_j1024_k32",
        "loglik_b1p0_i256_j256_k32",
        "part_update_b2p0_B4_m32_n32_k16_nomirror",  # ablation
    ]:
        assert needed in names, needed


def test_io_schema_consistent():
    for e in build_entries():
        first3 = [i["name"] for i in e["inputs"]][:3]
        assert first3 in (["ws", "hs", "vs"], ["w", "h", "v"])
        for io in e["inputs"] + e["outputs"]:
            assert io["dtype"] in ("f32", "u32")
            assert all(isinstance(d, int) for d in io["shape"])
        # input count matches the lowered arity
        if e["kind"] == "part_update":
            assert len(e["inputs"]) == 8
        elif e["kind"] == "ld_update":
            assert len(e["inputs"]) == 7
        else:
            assert len(e["inputs"]) == 3


def test_lower_small_part_update_roundtrip():
    """Lower the quickstart part_update to HLO text and check the text
    parses structurally (the numeric round-trip happens in Rust tests)."""
    import functools

    fn = functools.partial(model.part_update, beta=1.0, mirror=True)
    args = [
        spec((2, 32, 16)), spec((2, 16, 32)), spec((2, 32, 32)),
        spec(()), spec(()), spec(()), spec(()),
        spec((2,), jnp.uint32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text and "HloModule" in text
    assert text.count("parameter(") >= 8


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(tmp_path),
         "--only", "loglik_b1p0_i128_j128_k16"],
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
        capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    [e] = manifest["entries"]
    assert e["kind"] == "loglik"
    hlo = (tmp_path / e["file"]).read_text()
    assert "ENTRY" in hlo
    assert len(e["sha256"]) == 16


def test_part_update_hlo_mentions_rng_and_abs():
    """The lowered part_update must bake in the threefry noise path and
    the mirroring abs — i.e. nothing was constant-folded away."""
    import functools

    fn = functools.partial(model.part_update, beta=1.0, mirror=True)
    args = [
        spec((2, 32, 16)), spec((2, 16, 32)), spec((2, 32, 32)),
        spec(()), spec(()), spec(()), spec(()),
        spec((2,), jnp.uint32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    lowered_ops = text.lower()
    assert "xor" in lowered_ops or "rng" in lowered_ops  # threefry core
    assert "abs(" in lowered_ops or "abs." in lowered_ops
