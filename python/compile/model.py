"""L2 — the JAX model: PSGLD/LD update rules over the Pallas gradient
kernel, plus the monitors (loglik, RMSE).

These functions are what `aot.py` lowers to HLO text; the Rust runtime
executes them on the request path. Python is never imported at runtime.

Conventions shared with the Rust side (see rust/src/model/tweedie.rs):
  * the model is parameterised through |w|, |h| (mirroring trick, §3.2);
  * exponential priors E(w; lam_w), E(h; lam_h): grad log p = -lam*sign;
  * the data log-likelihood is the unnormalised Tweedie density
    -d_beta(v||mu)/phi (the mu-independent normaliser is dropped);
  * Langevin noise N(0, 2*eps) is generated inside the executable from a
    uint32[2] threefry seed input — Rust ships 8 bytes of key material
    per step instead of (I+J)*K floats.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.psgld_grads import MU_EPS, beta_divergence, psgld_grads


def block_update(w, h, v, eps, scale, lam_w, lam_h, seed, *, beta,
                 phi=1.0, mirror=True):
    """One SGLD update of a single (W_b, H_b) pair given data block V_b.

    Paper Eqs. 8-9: dW = eps*(scale * grad_loglik + grad_logprior) + psi,
    psi ~ N(0, 2 eps), followed by the optional mirroring step.
    `scale` carries the N/|Pi| bias-correction factor.
    """
    gw, gh, _ = psgld_grads(w, h, v, beta=beta, phi=phi)
    kw = jax.random.fold_in(seed, 0)
    kh = jax.random.fold_in(seed, 1)
    sd = jnp.sqrt(2.0 * eps)
    dw = eps * (scale * gw - lam_w * jnp.sign(w)) + sd * jax.random.normal(kw, w.shape)
    dh = eps * (scale * gh - lam_h * jnp.sign(h)) + sd * jax.random.normal(kh, h.shape)
    w2 = w + dw
    h2 = h + dh
    if mirror:
        w2 = jnp.abs(w2)
        h2 = jnp.abs(h2)
    return w2, h2


def part_update(ws, hs, vs, eps, scale, lam_w, lam_h, seed, *, beta,
                phi=1.0, mirror=True):
    """Batched update of all B blocks of a part — ONE dispatch per
    iteration, the analogue of the paper's one CUDA launch per part.

    ws: [B, m, K], hs: [B, K, n], vs: [B, m, n]. Block b of the part
    pairs row-stripe b with whatever column-stripe the coordinator
    stacked into slot b (the generalized diagonal is the coordinator's
    concern; the executable sees conditionally-independent blocks).
    """
    b = ws.shape[0]
    seeds = jax.vmap(lambda i: jax.random.fold_in(seed, i))(jnp.arange(b))
    upd = functools.partial(block_update, beta=beta, phi=phi, mirror=mirror)
    return jax.vmap(upd, in_axes=(0, 0, 0, None, None, None, None, 0))(
        ws, hs, vs, eps, scale, lam_w, lam_h, seeds
    )


def ld_update(w, h, v, eps, lam_w, lam_h, seed, *, beta, phi=1.0,
              mirror=True):
    """Full-batch Langevin dynamics step (the LD baseline): the block
    update over the whole matrix with scale = 1."""
    return block_update(w, h, v, eps, jnp.float32(1.0), lam_w, lam_h,
                        seed, beta=beta, phi=phi, mirror=mirror)


def loglik(w, h, v, *, beta, phi=1.0):
    """Unnormalised data log-likelihood of the full matrix (monitor)."""
    _, _, ll = psgld_grads(w, h, v, beta=beta, phi=phi)
    return ll[0, 0]


def log_posterior(w, h, v, lam_w, lam_h, *, beta, phi=1.0):
    """Joint unnormalised log posterior (data term + exponential priors)."""
    ll = loglik(w, h, v, beta=beta, phi=phi)
    lp = -lam_w * jnp.sum(jnp.abs(w)) - lam_h * jnp.sum(jnp.abs(h))
    return ll + lp


def rmse(w, h, v):
    """Root mean squared error between V and |W||H| (Fig. 5 monitor)."""
    mu = jnp.abs(w) @ jnp.abs(h)
    return jnp.sqrt(jnp.mean((v - mu) ** 2))


def predict(w, h):
    """Posterior-mean reconstruction from one sample: mu = |W||H|."""
    return jnp.abs(w) @ jnp.abs(h)
