"""AOT compiler: lower the L2 model functions to HLO *text* artifacts.

HLO text (NOT `.serialize()`d protos) is the interchange format: jax>=0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Run via `make artifacts`:
    cd python && python -m compile.aot --outdir ../artifacts

Emits one .hlo.txt per (function, beta, shape) variant plus
`manifest.json`, which the Rust runtime (rust/src/runtime/manifest.rs)
consumes to compile and dispatch executables.
"""

import argparse
import functools
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = "f32"
U32 = "u32"


def beta_tag(beta: float) -> str:
    return "b" + str(float(beta)).replace(".", "p").replace("-", "m")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


SCALAR = spec((), jnp.float32)
SEED = spec((2,), jnp.uint32)


def io_entry(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


# --------------------------------------------------------------------------
# Shape table — every executable the experiments need. Keyed by the
# experiment index in DESIGN.md §5.
# --------------------------------------------------------------------------

# (beta, B, m, n, k, mirror)
PART_UPDATES = [
    (1.0, 4, 32, 32, 16, True),    # quickstart
    (1.0, 8, 32, 32, 32, True),    # fig2a I=J=256
    (1.0, 16, 32, 32, 32, True),   # fig2a I=J=512
    (1.0, 32, 32, 32, 32, True),   # fig2a I=J=1024
    (0.5, 32, 32, 32, 32, True),   # fig2b compound Poisson
    (1.0, 8, 32, 32, 8, True),     # fig3 audio (256x256, K=8, B=8)
    (2.0, 4, 32, 32, 16, True),    # ablation: Gaussian + mirroring
    (2.0, 4, 32, 32, 16, False),   # ablation: Gaussian, no mirroring
]

# (beta, i, j, k, mirror)
LD_UPDATES = [
    (1.0, 128, 128, 16, True),
    (1.0, 256, 256, 32, True),
    (1.0, 512, 512, 32, True),
    (1.0, 1024, 1024, 32, True),
    (0.5, 1024, 1024, 32, True),
    (1.0, 256, 256, 8, True),
]

# (beta, i, j, k)
LOGLIKS = [
    (1.0, 128, 128, 16),
    (1.0, 256, 256, 32),
    (1.0, 512, 512, 32),
    (1.0, 1024, 1024, 32),
    (0.5, 1024, 1024, 32),
    (1.0, 256, 256, 8),
]


def build_entries():
    entries = []
    for beta, b, m, n, k, mirror in PART_UPDATES:
        name = f"part_update_{beta_tag(beta)}_B{b}_m{m}_n{n}_k{k}" + (
            "" if mirror else "_nomirror"
        )
        fn = functools.partial(model.part_update, beta=beta, mirror=mirror)
        args = [
            spec((b, m, k)), spec((b, k, n)), spec((b, m, n)),
            SCALAR, SCALAR, SCALAR, SCALAR, SEED,
        ]
        entries.append({
            "name": name,
            "kind": "part_update",
            "beta": beta, "phi": 1.0, "mirror": mirror,
            "b": b, "m": m, "n": n, "k": k,
            "fn": fn, "args": args,
            "inputs": [
                io_entry("ws", F32, (b, m, k)),
                io_entry("hs", F32, (b, k, n)),
                io_entry("vs", F32, (b, m, n)),
                io_entry("eps", F32, ()),
                io_entry("scale", F32, ()),
                io_entry("lam_w", F32, ()),
                io_entry("lam_h", F32, ()),
                io_entry("seed", U32, (2,)),
            ],
            "outputs": [
                io_entry("ws_next", F32, (b, m, k)),
                io_entry("hs_next", F32, (b, k, n)),
            ],
        })
    for beta, i, j, k, mirror in LD_UPDATES:
        name = f"ld_update_{beta_tag(beta)}_i{i}_j{j}_k{k}" + (
            "" if mirror else "_nomirror"
        )
        fn = functools.partial(model.ld_update, beta=beta, mirror=mirror)
        args = [
            spec((i, k)), spec((k, j)), spec((i, j)),
            SCALAR, SCALAR, SCALAR, SEED,
        ]
        entries.append({
            "name": name,
            "kind": "ld_update",
            "beta": beta, "phi": 1.0, "mirror": mirror,
            "i": i, "j": j, "k": k,
            "fn": fn, "args": args,
            "inputs": [
                io_entry("w", F32, (i, k)),
                io_entry("h", F32, (k, j)),
                io_entry("v", F32, (i, j)),
                io_entry("eps", F32, ()),
                io_entry("lam_w", F32, ()),
                io_entry("lam_h", F32, ()),
                io_entry("seed", U32, (2,)),
            ],
            "outputs": [
                io_entry("w_next", F32, (i, k)),
                io_entry("h_next", F32, (k, j)),
            ],
        })
    for beta, i, j, k in LOGLIKS:
        name = f"loglik_{beta_tag(beta)}_i{i}_j{j}_k{k}"
        fn = functools.partial(model.loglik, beta=beta)
        args = [spec((i, k)), spec((k, j)), spec((i, j))]
        entries.append({
            "name": name,
            "kind": "loglik",
            "beta": beta, "phi": 1.0, "mirror": True,
            "i": i, "j": j, "k": k,
            "fn": fn, "args": args,
            "inputs": [
                io_entry("w", F32, (i, k)),
                io_entry("h", F32, (k, j)),
                io_entry("v", F32, (i, j)),
            ],
            "outputs": [io_entry("ll", F32, ())],
        })
    return entries


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    entries = build_entries()
    if args.only:
        entries = [e for e in entries if args.only in e["name"]]
    if args.list:
        for e in entries:
            print(e["name"])
        return 0

    manifest = {"version": 1, "entries": []}
    for e in entries:
        fname = e["name"] + ".hlo.txt"
        path = outdir / fname
        lowered = jax.jit(e.pop("fn")).lower(*e.pop("args"))
        text = to_hlo_text(lowered)
        path.write_text(text)
        e["file"] = fname
        e["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["entries"].append(e)
        print(f"  {fname}  ({len(text)} chars)", file=sys.stderr)

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(entries)} artifacts + manifest.json -> {outdir}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
