"""L1 — Pallas kernel for the PSGLD compute hot-spot.

For one block (W_b, H_b, V_b) of a part, computes the sum over the block
of the per-entry Tweedie log-likelihood gradients plus the (unnormalised)
log-likelihood itself:

    mu  = |W| @ |H|                       (MXU matmul)
    E   = (V - mu) * mu^(beta-2) / phi    (VPU elementwise)
    G_W = sign(W) * (E @ |H|^T)           (MXU matmul)
    G_H = sign(H) * (|W|^T @ E)           (MXU matmul)
    ll  = -sum(d_beta(V || mu)) / phi

The kernel is tiled over (m, n) with BlockSpec; the K dimension (small:
8..64 in every experiment) stays resident in VMEM. G_W accumulates across
the n-tile grid axis, G_H across the m-tile axis and ll across both —
the classic Pallas revisiting-output accumulation pattern.

Hardware adaptation (paper used CUDA threadblocks + shared memory): the
BlockSpec pipeline stages HBM->VMEM tiles with automatic double
buffering; the three GEMMs target the MXU; the elementwise weight runs
fused on the VPU between them. `interpret=True` always (the CPU PJRT
plugin cannot execute Mosaic custom-calls); real-TPU efficiency is
estimated from the VMEM footprint in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Floor for mu: beta < 2 weights divide by powers of mu.
MU_EPS = 1e-6
# Floor for v inside log(v/mu) when beta == 0 (Itakura-Saito needs v > 0).
V_EPS = 1e-12


def elementwise_weight(mu, beta):
    """mu^(beta-2), special-cased for the betas the paper uses."""
    if beta == 2.0:
        return jnp.ones_like(mu)
    if beta == 1.0:
        return 1.0 / mu
    if beta == 0.0:
        return 1.0 / (mu * mu)
    return mu ** (beta - 2.0)


def beta_divergence(v, mu, beta):
    """d_beta(v || mu), elementwise. Special cases beta in {0, 1, 2}."""
    if beta == 1.0:  # generalised KL (Poisson)
        # xlogy-safe: v * log(v/mu) - v + mu, with v=0 -> mu
        return jnp.where(v > 0, v * jnp.log(jnp.maximum(v, V_EPS) / mu), 0.0) - v + mu
    if beta == 0.0:  # Itakura-Saito (gamma)
        vs = jnp.maximum(v, V_EPS)
        return vs / mu - jnp.log(vs / mu) - 1.0
    if beta == 2.0:  # squared Euclidean (Gaussian)
        return 0.5 * (v - mu) ** 2
    return (
        jnp.maximum(v, 0.0) ** beta / (beta * (beta - 1.0))
        - v * mu ** (beta - 1.0) / (beta - 1.0)
        + mu**beta / beta
    )


def _grads_kernel(w_ref, h_ref, v_ref, gw_ref, gh_ref, ll_ref, *, beta, phi):
    i, j = pl.program_id(0), pl.program_id(1)
    w = w_ref[...]
    h = h_ref[...]
    v = v_ref[...]
    wa = jnp.abs(w)
    ha = jnp.abs(h)
    mu = wa @ ha + MU_EPS
    e = (v - mu) * elementwise_weight(mu, beta) * (1.0 / phi)

    @pl.when(j == 0)
    def _():
        gw_ref[...] = jnp.zeros_like(gw_ref)

    gw_ref[...] += jnp.sign(w) * (e @ ha.T)

    @pl.when(i == 0)
    def _():
        gh_ref[...] = jnp.zeros_like(gh_ref)

    gh_ref[...] += jnp.sign(h) * (wa.T @ e)

    @pl.when((i == 0) & (j == 0))
    def _():
        ll_ref[...] = jnp.zeros_like(ll_ref)

    ll_ref[...] += -jnp.sum(beta_divergence(v, mu, beta))[None, None] * (1.0 / phi)


def pick_tile(dim, pref=128):
    """Largest power-of-two tile <= pref that divides dim."""
    t = min(pref, dim)
    while dim % t != 0:
        t //= 2
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("beta", "phi", "bm", "bn"))
def psgld_grads(w, h, v, *, beta, phi=1.0, bm=None, bn=None):
    """Blockwise-summed gradients + loglik for one (W_b, H_b, V_b) block.

    Returns (G_W [m,K], G_H [K,n], ll [1,1]).
    """
    m, k = w.shape
    k2, n = h.shape
    assert k == k2 and v.shape == (m, n), (w.shape, h.shape, v.shape)
    bm = bm or pick_tile(m)
    bn = bn or pick_tile(n)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_grads_kernel, beta=float(beta), phi=float(phi))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), w.dtype),
            jax.ShapeDtypeStruct((k, n), h.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(w, h, v)


def vmem_report(m, n, k, bm=None, bn=None, dtype_bytes=4):
    """Estimated VMEM residency per grid step (for DESIGN.md §8).

    With double buffering the pipeline holds 2x the input tiles plus the
    output accumulators resident.
    """
    bm = bm or pick_tile(m)
    bn = bn or pick_tile(n)
    tiles = {
        "w_tile": bm * k,
        "h_tile": k * bn,
        "v_tile": bm * bn,
        "gw_acc": bm * k,
        "gh_acc": k * bn,
    }
    in_bytes = (tiles["w_tile"] + tiles["h_tile"] + tiles["v_tile"]) * dtype_bytes
    acc_bytes = (tiles["gw_acc"] + tiles["gh_acc"] + 1) * dtype_bytes
    total = 2 * in_bytes + acc_bytes  # 2x: double buffering
    flops = 3 * 2 * m * n * k  # three GEMMs over the full block
    return {
        "bm": bm,
        "bn": bn,
        "vmem_bytes": total,
        "fits_16MiB": total < 16 * 2**20,
        "gemm_flops_per_block": flops,
    }
