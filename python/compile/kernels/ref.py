"""Pure-jnp oracle for the Pallas kernel and the L2 update rules.

Everything here is the straight-line textbook implementation of the
paper's equations (Eqs. 8, 9 and the Tweedie log-likelihood), used by
pytest to validate the Pallas kernel and the lowered model functions.
"""

import jax
import jax.numpy as jnp

from .psgld_grads import MU_EPS, beta_divergence, elementwise_weight


def grads_ref(w, h, v, *, beta, phi=1.0):
    """Reference (G_W, G_H, ll) for one block — mirrors psgld_grads."""
    wa, ha = jnp.abs(w), jnp.abs(h)
    mu = wa @ ha + MU_EPS
    e = (v - mu) * elementwise_weight(mu, beta) / phi
    gw = jnp.sign(w) * (e @ ha.T)
    gh = jnp.sign(h) * (wa.T @ e)
    ll = -jnp.sum(beta_divergence(v, mu, beta)) / phi
    return gw, gh, jnp.reshape(ll, (1, 1))


def block_update_ref(w, h, v, eps, scale, lam_w, lam_h, seed, *, beta,
                     phi=1.0, mirror=True):
    """Reference SGLD block update (paper Eqs. 8-9 + mirroring)."""
    gw, gh, _ = grads_ref(w, h, v, beta=beta, phi=phi)
    kw = jax.random.fold_in(seed, 0)
    kh = jax.random.fold_in(seed, 1)
    sd = jnp.sqrt(2.0 * eps)
    dw = eps * (scale * gw - lam_w * jnp.sign(w)) + sd * jax.random.normal(kw, w.shape)
    dh = eps * (scale * gh - lam_h * jnp.sign(h)) + sd * jax.random.normal(kh, h.shape)
    w2, h2 = w + dw, h + dh
    if mirror:
        w2, h2 = jnp.abs(w2), jnp.abs(h2)
    return w2, h2


def loglik_ref(w, h, v, *, beta, phi=1.0):
    """Unnormalised Tweedie data log-likelihood sum_ij -d_beta(v||mu)/phi."""
    mu = jnp.abs(w) @ jnp.abs(h) + MU_EPS
    return -jnp.sum(beta_divergence(v, mu, beta)) / phi


def rmse_ref(w, h, v):
    mu = jnp.abs(w) @ jnp.abs(h)
    return jnp.sqrt(jnp.mean((v - mu) ** 2))
