//! Distributed-setting simulator (paper §4.3).
//!
//! The paper ran PSGLD on 15 physical nodes × 8 cores with OpenMPI,
//! using the ring mechanism of Fig. 4: node `n` owns `W_b` permanently
//! and passes its current `H_b` block to node `(n mod B) + 1` after
//! every iteration, which implicitly selects the next part. No such
//! cluster exists in this environment, so we build a **virtual-time
//! simulator** (substitution documented in DESIGN.md §3) with an
//! explicit cost model:
//!
//! * per-iteration compute per node: `block_entries / entry_rate +
//!   factor_entries / noise_rate` (rates either calibrated from the
//!   measured native kernel or set to paper-hardware values);
//! * ring communication: the `B` logical nodes are packed onto
//!   `phys_nodes` physical hosts; co-located ranks serialise their
//!   message latencies on the shared NIC (`ceil(B/phys) · latency`)
//!   while payloads (`|H_b| = (J/B)·K·4` bytes) move at `bandwidth`;
//! * DSGLD's sync instead ships *all* parameters every `sync_every`
//!   iterations (ring all-reduce), which is exactly the communication
//!   gap the paper's §1 calls out.
//!
//! `Fidelity::Full` executes the real block updates (bitwise identical
//! to shared-memory PSGLD — asserted in tests) while charging virtual
//! time; `Fidelity::Timing` charges time only, which lets the
//! 683 584 × 4 580 288 weak-scaling point of Fig. 6(b) run without
//! allocating 640M entries.
//!
//! # DESIGN: the asynchronous fault-injecting executor
//!
//! The synchronous simulator above advances all nodes in lock-step — a
//! barrier per iteration — which models the paper's §4.3 cluster but
//! not a production one, where stragglers, crashes and lost messages
//! are the normal case. The `async_sim` submodule therefore runs the
//! same chain through a **discrete-event loop**:
//!
//! * **Events** ([`event`]): `NodeFinish`, `MsgArrive`, `RetryTimer`,
//!   `RestartDone` on a virtual-time priority queue. Ties are resolved
//!   by a pluggable [`TieBreak`] policy that must never influence the
//!   chain (only per-`(seed, t, block)` RNG streams do) — tests permute
//!   the policy to pin this.
//! * **Bounded staleness** ([`staleness`]): every cached stripe copy
//!   carries a *lineage* version — the number of block updates baked
//!   into its content. Executing on a copy deepens its lineage by one
//!   (stale content does not become fresh by being updated), and an
//!   arriving ring message replaces the cache only when it carries a
//!   deeper lineage. Staleness of a consumption at iteration `t` is
//!   `(t - 1) - version`: how many updates short of the chain front
//!   the copy was. Node `i` may start iteration `t` while that
//!   staleness is at most `tau`; past the bound it stalls until a
//!   deeper copy arrives. Consequences: (a) hand-offs inherit their
//!   producer's deficit and a lap-old reuse accrues a further
//!   `B - 1`, so staleness *accumulates* across stale executions and
//!   any fast node more than ~`B * (tau + 1)` iterations ahead of the
//!   slowest producer is forced to stall — the bound simultaneously
//!   caps bias (Chen et al. 2016), lead, and open-snapshot memory;
//!   (b) a superseded slow producer's update can be dropped on merge
//!   (its lineage is shallower than the branch that bypassed it) —
//!   the usual divergence price of asynchrony. Small `tau` behaves
//!   near-synchronously; `tau >= B - 1` admits genuinely lap-stale
//!   updates — the regime the convergence tests exercise. The
//!   [`StalenessLedger`] refuses to record a bound violation, making
//!   "staleness never exceeds tau" an executor invariant rather than
//!   a hope.
//! * **Faults** ([`fault`]): a [`FaultPlan`] is a deterministic
//!   schedule keyed by `(node, iteration)` — straggler windows multiply
//!   compute time, crash rules trigger a coordinated rollback to the
//!   last consistent checkpoint (via [`crate::coordinator::Checkpoint`]),
//!   drop/delay rules act on the ring messages, with timeout +
//!   exponential-backoff retries that fail loudly past `max_retries`.
//! * **Consistent snapshots**: updates apply at iteration start; a
//!   per-iteration slot collects every node's updated stripes and
//!   completes when all `B` nodes have finished that iteration —
//!   completion is monotone in `t`, so monitoring, checkpointing and
//!   recovery all see exact global states without ever imposing a
//!   barrier on the executor.
//!
//! With `tau = 0` and an empty plan the async executor reproduces the
//! synchronous chains bitwise (for mirror models, whose nonneg fast
//! path needs no global rescan); `benches/fault_sweep.rs` measures
//! throughput and held-out likelihood across crash-rate × tau.

pub mod async_sim;
pub mod event;
pub mod fault;
pub mod staleness;

pub use async_sim::{psgld_distributed_async, AsyncSimReport};
pub use event::{EventKind, EventQueue, Msg, TieBreak};
pub use fault::{CrashRule, DelayRule, DropRule, FaultPlan, FaultRates, StragglerRule};
pub use staleness::{StaleRecord, StalenessLedger};

use crate::config::RunConfig;
use crate::data::sparse::{BlockedSparse, Csr};
use crate::kernels::sgld_apply_core;
use crate::linalg::Mat;
use crate::metrics::Trace;
use crate::model::NmfModel;
use crate::partition::{Part, PartScheduler};
use crate::rng::Rng;
use crate::samplers::{sparse_block_langevin, FactorState};
use crate::util::parallel::{default_threads, SendPtr, WorkerPool};
use crate::Result;

/// Network cost model of the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// One-way message latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Physical hosts the logical nodes are packed onto.
    pub phys_nodes: usize,
}

impl NetworkModel {
    /// The paper's cluster: 15 hosts × 8 cores, ~1 GbE-class
    /// interconnect. The 0.8 ms effective per-message latency reflects
    /// the 2015 Ethernet + MPI stack with fully subscribed cores (no
    /// spare core for progress threads); it places the strong-scaling
    /// knee between B = 90 and B = 120, where the paper observed it.
    pub fn paper_cluster() -> Self {
        NetworkModel { latency_s: 8e-4, bandwidth_bps: 1.25e9, phys_nodes: 15 }
    }

    /// Latency serialisation factor: co-located ranks share a NIC.
    pub fn contention(&self, b: usize) -> f64 {
        (b as f64 / self.phys_nodes as f64).ceil().max(1.0)
    }

    /// Time for the concurrent ring exchange of one `bytes`-sized block
    /// per node.
    pub fn ring_exchange_s(&self, b: usize, bytes: usize) -> f64 {
        self.contention(b) * self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Ring all-reduce of `bytes` over `b` nodes (DSGLD sync).
    pub fn allreduce_s(&self, b: usize, bytes: usize) -> f64 {
        if b <= 1 {
            return 0.0;
        }
        let steps = 2 * (b - 1);
        steps as f64 * (self.contention(b) * self.latency_s)
            + 2.0 * bytes as f64 / self.bandwidth_bps
    }
}

/// Per-node compute cost model.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Observed-entry gradient updates per second per node.
    pub entry_rate: f64,
    /// Langevin noise draws (factor entries) per second per node.
    pub noise_rate: f64,
}

impl ComputeModel {
    /// Rates matching the paper's single-core C implementation
    /// (inferred from Fig. 5: ~2 s/iteration at B=15, K=50, 10M nnz).
    pub fn paper_node() -> Self {
        ComputeModel { entry_rate: 5e5, noise_rate: 5e7 }
    }

    /// Calibrate from this machine's native kernel (used when relating
    /// simulated results to local wall-clock runs).
    pub fn calibrate(k: usize) -> Self {
        use std::time::Instant;
        let mut rng = Rng::seed_from(0xca11b);
        let m = 128;
        let w = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let ht = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let v = Mat::uniform(m, m, 0.0, 4.0, &mut rng);
        let mut gw = vec![0f32; m * k];
        let mut ght = vec![0f32; m * k];
        let tick = Instant::now();
        let reps = 8;
        for _ in 0..reps {
            gw.fill(0.0);
            ght.fill(0.0);
            crate::kernels::grads_dense_core(
                w.as_slice(), m, ht.as_slice(), m, k, v.as_slice(), 1.0, 1.0,
                &mut gw, &mut ght,
            );
        }
        let per_entry = tick.elapsed().as_secs_f64() / (reps * m * m) as f64;

        let mut buf = vec![0f32; 1 << 16];
        let zeros = vec![0f32; 1 << 16];
        let tick = Instant::now();
        let mut trng = Rng::seed_from(1);
        let mut noise_scratch = crate::util::parallel::ScratchArena::new();
        sgld_apply_core(
            &mut buf,
            &zeros,
            0.01,
            1.0,
            0.0,
            true,
            &mut trng,
            &mut noise_scratch,
        );
        let per_noise = tick.elapsed().as_secs_f64() / (1 << 16) as f64;
        ComputeModel {
            entry_rate: 1.0 / per_entry.max(1e-12),
            noise_rate: 1.0 / per_noise.max(1e-12),
        }
    }

    /// Seconds to process a block with `entries` observations and
    /// `factor_entries` factor parameters.
    pub fn block_time_s(&self, entries: usize, factor_entries: usize) -> f64 {
        entries as f64 / self.entry_rate + factor_entries as f64 / self.noise_rate
    }
}

/// Execution fidelity of the simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    /// Run the real block updates (virtual time + real chain).
    Full,
    /// Charge virtual time only (no state, arbitrary scale).
    Timing,
}

/// Result of a simulated distributed run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total simulated wall time.
    pub virtual_seconds: f64,
    /// Of which communication.
    pub comm_seconds: f64,
    /// Of which compute (max over nodes per iteration, summed).
    pub compute_seconds: f64,
    /// Iterations executed.
    pub iterations: u64,
    /// Monitor trace (Full fidelity only; virtual-time x-axis).
    pub trace: Option<Trace>,
    /// Final state (Full fidelity only).
    pub state: Option<FactorState>,
}

/// Distributed PSGLD over a sparse matrix in **Full** fidelity: executes
/// the exact PSGLD chain (identical to the shared-memory sampler given
/// the same seed) while accounting virtual time per the cost model.
#[allow(clippy::too_many_arguments)]
pub fn psgld_distributed_full(
    v: &Csr,
    model: &NmfModel,
    b: usize,
    run: &RunConfig,
    seed: u64,
    net: &NetworkModel,
    compute: &ComputeModel,
    mut monitor: impl FnMut(&FactorState) -> f64,
) -> Result<SimReport> {
    let blocked = BlockedSparse::from_csr(v, b)?;
    let grid = blocked.grid().clone();
    let k = model.k;
    let mut rng = Rng::derive(seed, &[0x9516_1d]);
    let mut state = FactorState::from_prior(model, grid.rows(), grid.cols(), &mut rng);
    let mut scheduler = PartScheduler::new(run.schedule, b);

    // Persistent per-"node" resources: the simulated nodes run on the
    // worker pool, with per-block gradient buffers reused every
    // iteration (the steady-state loop allocates nothing).
    let max_n = (0..b).map(|bj| grid.col_range(bj).len()).max().unwrap_or(0);
    let mut scratch: Vec<(Vec<f32>, Vec<f32>)> = (0..b)
        .map(|bi| (vec![0f32; grid.row_range(bi).len() * k], vec![0f32; max_n * k]))
        .collect();
    let mut pool = WorkerPool::new(default_threads().min(b));
    let mut part = Part::identity(b);

    let mut vclock = 0.0f64;
    let (mut comm_s, mut compute_s) = (0.0f64, 0.0f64);
    let mut trace = Trace::new("psgld_dist");
    trace.push(0, 0.0, monitor(&state));

    for t in 1..=run.t_total {
        let mut step_rng = Rng::derive(seed, &[t, 0xcafe]);
        scheduler.next_part_into(&mut step_rng, &mut part);
        let eps = run.step.eps(t) as f32;
        let scale = blocked.scale(&part);

        // --- compute phase: nodes run their blocks concurrently -------
        // virtual-time accounting stays serial (cheap), the actual block
        // updates fan out over the pool with the same RNG tagging as the
        // shared-memory PSGLD, so the chain stays bitwise identical.
        let mut max_node_time = 0.0f64;
        for bi in 0..b {
            let bj = part.perm[bi];
            let (m, n) = (grid.row_range(bi).len(), grid.col_range(bj).len());
            max_node_time = max_node_time
                .max(compute.block_time_s(blocked.block(bi, bj).nnz(), (m + n) * k));
        }
        {
            // once-per-part nonneg decision, computed exactly as the
            // shared-memory Psgld does it (bitwise-equality contract)
            let nonneg = crate::kernels::nonneg_hint(
                model.mirror,
                state.w.as_slice(),
                state.ht.as_slice(),
                blocked.nnz(),
            );
            let w_ptr = SendPtr::new(state.w.as_mut_slice().as_mut_ptr());
            let ht_ptr = SendPtr::new(state.ht.as_mut_slice().as_mut_ptr());
            let scratch_ptr = SendPtr::new(scratch.as_mut_ptr());
            let (grid, blocked, part) = (&grid, &blocked, &part);
            pool.for_each_index(b, move |arena, bi| {
                let bj = part.perm[bi];
                let rows = grid.row_range(bi);
                let cols = grid.col_range(bj);
                let (m, n) = (rows.len(), cols.len());
                // SAFETY: row stripes disjoint across bi, column stripes
                // disjoint across bj = perm[bi] (bijection), scratch[bi]
                // touched by exactly one task.
                let w_slice = unsafe {
                    std::slice::from_raw_parts_mut(w_ptr.get().add(rows.start * k), m * k)
                };
                let ht_slice = unsafe {
                    std::slice::from_raw_parts_mut(ht_ptr.get().add(cols.start * k), n * k)
                };
                let sb = unsafe { &mut *scratch_ptr.get().add(bi) };
                let gw = &mut sb.0[..m * k];
                let ght = &mut sb.1[..n * k];
                // shared canonical block body (samplers/block_step.rs)
                sparse_block_langevin(
                    w_slice, ht_slice, k, blocked.block(bi, bj), model, nonneg,
                    eps, scale, seed, t, bi as u64, gw, ght, arena,
                );
            });
        }

        // --- communication phase: ring-rotate the H blocks (Fig. 4) ---
        let max_h_bytes = (0..b)
            .map(|bj| grid.col_range(bj).len() * k * std::mem::size_of::<f32>())
            .max()
            .unwrap_or(0);
        let comm = net.ring_exchange_s(b, max_h_bytes);

        vclock += max_node_time + comm;
        compute_s += max_node_time;
        comm_s += comm;

        if t % run.monitor_every == 0 || t == run.t_total {
            trace.push(t, vclock, monitor(&state));
        }
    }

    Ok(SimReport {
        virtual_seconds: vclock,
        comm_seconds: comm_s,
        compute_seconds: compute_s,
        iterations: run.t_total,
        trace: Some(trace),
        state: Some(state),
    })
}

/// Workload description for **Timing**-fidelity simulations (no data is
/// materialised, so Fig. 6(b)'s 640M-entry matrix is representable).
#[derive(Clone, Copy, Debug)]
pub struct TimingWorkload {
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub k: usize,
}

impl TimingWorkload {
    /// MovieLens 10M at the paper's dimensions.
    pub fn ml10m(k: usize) -> Self {
        TimingWorkload {
            rows: crate::data::movielens::ML10M_MOVIES,
            cols: crate::data::movielens::ML10M_USERS,
            nnz: crate::data::movielens::ML10M_RATINGS as u64,
            k,
        }
    }

    /// Duplicate both dimensions `times` times (Fig. 6(b) growth rule:
    /// elements quadruple per step).
    pub fn doubled(&self, times: u32) -> Self {
        TimingWorkload {
            rows: self.rows << times,
            cols: self.cols << times,
            nnz: self.nnz << (2 * times),
            k: self.k,
        }
    }
}

/// Timing-only distributed PSGLD: `iters` iterations over `b` nodes.
pub fn psgld_distributed_timing(
    w: &TimingWorkload,
    b: usize,
    iters: u64,
    net: &NetworkModel,
    compute: &ComputeModel,
) -> SimReport {
    // uniform-grid expectation: each block holds nnz/B² entries
    let block_entries = (w.nnz as f64 / (b * b) as f64).ceil() as usize;
    let factor_entries = (w.rows / b + w.cols / b) * w.k;
    let h_bytes = (w.cols / b) * w.k * std::mem::size_of::<f32>();

    let per_iter_compute = compute.block_time_s(block_entries, factor_entries);
    let per_iter_comm = net.ring_exchange_s(b, h_bytes);
    SimReport {
        virtual_seconds: (per_iter_compute + per_iter_comm) * iters as f64,
        comm_seconds: per_iter_comm * iters as f64,
        compute_seconds: per_iter_compute * iters as f64,
        iterations: iters,
        trace: None,
        state: None,
    }
}

/// Timing-only distributed DSGLD (Ahn et al. 2014): every worker holds
/// full replicas; full parameters are all-reduced every `sync_every`
/// iterations. Comparator for the communication-cost claims of §1.
pub fn dsgld_distributed_timing(
    w: &TimingWorkload,
    workers: usize,
    omega: usize,
    sync_every: u64,
    iters: u64,
    net: &NetworkModel,
    compute: &ComputeModel,
) -> SimReport {
    let factor_entries = (w.rows + w.cols) * w.k; // FULL parameter noise
    let per_iter_compute = compute.block_time_s(omega, factor_entries);
    let param_bytes = factor_entries * std::mem::size_of::<f32>();
    let syncs = iters / sync_every.max(1);
    let comm = syncs as f64 * net.allreduce_s(workers, param_bytes);
    SimReport {
        virtual_seconds: per_iter_compute * iters as f64 + comm,
        comm_seconds: comm,
        compute_seconds: per_iter_compute * iters as f64,
        iterations: iters,
        trace: None,
        state: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, StepSchedule};
    use crate::data::movielens;
    use crate::samplers::{Psgld, Sampler};

    #[test]
    fn network_contention_steps() {
        let net = NetworkModel::paper_cluster();
        assert_eq!(net.contention(5), 1.0);
        assert_eq!(net.contention(15), 1.0);
        assert_eq!(net.contention(16), 2.0);
        assert_eq!(net.contention(120), 8.0);
    }

    #[test]
    fn full_fidelity_matches_shared_memory_chain() {
        // identical seeds => identical chains (the simulator IS PSGLD)
        let csr = movielens::movielens_like_dims(48, 64, 600, 4, 7);
        let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
        let run = RunConfig::quick(40)
            .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        let net = NetworkModel::paper_cluster();
        let compute = ComputeModel::paper_node();
        let rep = psgld_distributed_full(
            &csr, &model, 4, &run, 99, &net, &compute, |_| 0.0,
        )
        .unwrap();
        let mut shm = Psgld::new_sparse(&csr, &model, 4, run.clone(), 99).unwrap();
        for t in 1..=40 {
            shm.step(t);
        }
        let sim_state = rep.state.unwrap();
        assert_eq!(sim_state.w, shm.state().w);
        assert_eq!(sim_state.ht, shm.state().ht);
        assert!(rep.virtual_seconds > 0.0);
        assert!(rep.comm_seconds > 0.0);
    }

    #[test]
    fn strong_scaling_has_sweet_spot() {
        // Fig 6(a) shape: falls steeply, then communication dominates
        let wl = TimingWorkload::ml10m(50);
        let net = NetworkModel::paper_cluster();
        let compute = ComputeModel::paper_node();
        let times: Vec<f64> = [5usize, 15, 30, 60, 90, 120]
            .iter()
            .map(|&b| psgld_distributed_timing(&wl, b, 100, &net, &compute).virtual_seconds)
            .collect();
        // steep initial drop (roughly quadratic from 5 to 15)
        assert!(times[0] / times[1] > 5.0, "{times:?}");
        // monotone decrease until some sweet spot...
        let min_idx = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(min_idx >= 2 && min_idx < 5, "sweet spot at idx {min_idx}: {times:?}");
        // ...and the 120-node point is worse than the sweet spot
        assert!(times[5] > times[min_idx], "{times:?}");
    }

    #[test]
    fn weak_scaling_roughly_flat() {
        // Fig 6(b): data ×4, nodes ×2 per step -> time nearly constant
        let net = NetworkModel::paper_cluster();
        let compute = ComputeModel::paper_node();
        let base = TimingWorkload::ml10m(50);
        let t0 = psgld_distributed_timing(&base, 15, 10, &net, &compute).virtual_seconds;
        let t3 = psgld_distributed_timing(&base.doubled(3), 120, 10, &net, &compute)
            .virtual_seconds;
        assert!(
            t3 < 1.6 * t0,
            "weak scaling should be nearly flat: {t0} -> {t3}"
        );
        // while the data grew 64x
        assert_eq!(base.doubled(3).nnz, base.nnz * 64);
    }

    #[test]
    fn dsgld_ships_more_bytes_than_psgld() {
        // §1 claim: PSGLD communicates only small parts of H; DSGLD all
        // of W and H. Compare per-iteration comm at the same workload.
        let wl = TimingWorkload::ml10m(50);
        let net = NetworkModel::paper_cluster();
        let compute = ComputeModel::paper_node();
        let iters = 100;
        let p = psgld_distributed_timing(&wl, 15, iters, &net, &compute);
        let d = dsgld_distributed_timing(&wl, 15, wl.nnz as usize / 15 / 100, 2, iters,
                                         &net, &compute);
        assert!(
            d.comm_seconds > 10.0 * p.comm_seconds,
            "DSGLD comm {} vs PSGLD comm {}",
            d.comm_seconds,
            p.comm_seconds
        );
    }

    #[test]
    fn calibration_produces_sane_rates() {
        let c = ComputeModel::calibrate(8);
        assert!(c.entry_rate > 1e5, "entry rate {}", c.entry_rate);
        assert!(c.noise_rate > 1e6, "noise rate {}", c.noise_rate);
    }
}
