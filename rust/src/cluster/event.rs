//! Discrete-event machinery for the asynchronous cluster simulator:
//! typed events, a virtual-time priority queue, and pluggable
//! tie-breaking.
//!
//! Virtual time is an `f64` of seconds. Events at equal times are
//! ordered by a [`TieBreak`] policy and then by insertion sequence; the
//! determinism tests permute the policy to prove the *chain* never
//! depends on pop order among ties (only per-`(seed, t, block)` RNG
//! streams touch the chain).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::rng::splitmix64;

/// A ring hand-off in flight: node `from` produced column-stripe
/// `block` of `H` at iteration `produced_at` and sends it to the node
/// that consumes the stripe next.
#[derive(Clone, Debug)]
pub struct Msg {
    pub from: usize,
    pub to: usize,
    /// Column-stripe index `0..B`.
    pub block: usize,
    /// Lineage depth of the payload: how many block updates are baked
    /// into it. Receivers keep whichever copy is deeper.
    pub version: u64,
    /// Iteration at which the payload was produced; fault rules for
    /// drops/delays are keyed on `(from, produced_at)`.
    pub produced_at: u64,
    /// Transmission attempt, 0-based; bumped on every retry.
    pub attempt: u32,
    /// The stripe content (`cols × K`, row-major).
    pub data: Vec<f32>,
}

/// What happens when an event fires.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// Node `node` finishes the compute phase of iteration `t`.
    NodeFinish { node: usize, t: u64 },
    /// A ring message reaches its destination.
    MsgArrive(Msg),
    /// A sender's retransmission timer expires.
    RetryTimer(Msg),
    /// A crashed-and-rolled-back cluster comes back up.
    RestartDone,
}

impl EventKind {
    /// The node an event concerns (destination for messages); feeds the
    /// tie-break key only, never the chain.
    fn node(&self) -> usize {
        match self {
            EventKind::NodeFinish { node, .. } => *node,
            EventKind::MsgArrive(m) | EventKind::RetryTimer(m) => m.to,
            EventKind::RestartDone => 0,
        }
    }
}

/// Order of events that share an identical virtual timestamp. The
/// simulated chain must be invariant under all of these (pinned by
/// `tests/fault_injection.rs`); the knob exists precisely so tests can
/// permute it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TieBreak {
    /// Insertion order (the default).
    Fifo,
    /// Reverse insertion order.
    Lifo,
    /// Highest node index first.
    NodeDesc,
    /// Pseudo-random order keyed by the salt.
    Hashed(u64),
}

impl TieBreak {
    fn key(&self, kind: &EventKind, seq: u64) -> u64 {
        match *self {
            TieBreak::Fifo => 0, // fall through to ascending seq
            TieBreak::Lifo => u64::MAX - seq,
            TieBreak::NodeDesc => u64::MAX - kind.node() as u64,
            TieBreak::Hashed(salt) => {
                let mut s = salt ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (kind.node() as u64);
                splitmix64(&mut s)
            }
        }
    }
}

#[derive(Clone, Debug)]
struct Event {
    time: f64,
    key: u64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed so the max-heap pops the earliest event; seq last so
        // ordering is always total and deterministic
        other
            .time
            .total_cmp(&self.time)
            .then(other.key.cmp(&self.key))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Virtual-time event queue with deterministic, policy-driven
/// tie-breaking.
#[derive(Debug)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    tie: TieBreak,
    seq: u64,
}

impl EventQueue {
    pub fn new(tie: TieBreak) -> Self {
        EventQueue { heap: BinaryHeap::new(), tie, seq: 0 }
    }

    /// Schedule `kind` to fire at virtual time `time`.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "event time must be finite");
        let key = self.tie.key(&kind, self.seq);
        self.heap.push(Event { time, key, seq: self.seq, kind });
        self.seq += 1;
    }

    /// Pop the earliest event (ties resolved by policy, then sequence).
    pub fn pop(&mut self) -> Option<(f64, EventKind)> {
        self.heap.pop().map(|e| (e.time, e.kind))
    }

    /// Drop every pending event (crash rollback discards in-flight work).
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new(TieBreak::Fifo);
        q.push(2.0, EventKind::RestartDone);
        q.push(0.5, EventKind::NodeFinish { node: 1, t: 3 });
        q.push(1.0, EventKind::NodeFinish { node: 0, t: 1 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn fifo_ties_pop_in_insertion_order() {
        let mut q = EventQueue::new(TieBreak::Fifo);
        for node in 0..5 {
            q.push(1.0, EventKind::NodeFinish { node, t: 1 });
        }
        let nodes: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, k)| match k {
                EventKind::NodeFinish { node, .. } => node,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(nodes, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lifo_and_node_desc_reverse_ties() {
        for tie in [TieBreak::Lifo, TieBreak::NodeDesc] {
            let mut q = EventQueue::new(tie);
            for node in 0..4 {
                q.push(1.0, EventKind::NodeFinish { node, t: 1 });
            }
            let nodes: Vec<usize> = std::iter::from_fn(|| {
                q.pop().map(|(_, k)| match k {
                    EventKind::NodeFinish { node, .. } => node,
                    _ => unreachable!(),
                })
            })
            .collect();
            assert_eq!(nodes, vec![3, 2, 1, 0], "{tie:?}");
        }
    }

    #[test]
    fn hashed_ties_are_deterministic_per_salt() {
        let order = |salt: u64| -> Vec<usize> {
            let mut q = EventQueue::new(TieBreak::Hashed(salt));
            for node in 0..6 {
                q.push(1.0, EventKind::NodeFinish { node, t: 1 });
            }
            std::iter::from_fn(|| {
                q.pop().map(|(_, k)| match k {
                    EventKind::NodeFinish { node, .. } => node,
                    _ => unreachable!(),
                })
            })
            .collect()
        };
        assert_eq!(order(7), order(7));
        assert_ne!(order(7), order(8), "different salts should permute ties");
    }

    #[test]
    fn clear_empties_the_queue() {
        let mut q = EventQueue::new(TieBreak::Fifo);
        q.push(1.0, EventKind::RestartDone);
        assert_eq!(q.len(), 1);
        q.clear();
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }
}
