//! Deterministic fault schedules for the async cluster simulator.
//!
//! A [`FaultPlan`] is a *data structure*, not a random process: every
//! straggler window, crash, drop and delay is keyed by logical
//! coordinates (node index, iteration number) — never by wall or
//! virtual time — so replaying the same plan yields the same run,
//! event-for-event. [`FaultPlan::seeded`] derives a plan
//! pseudo-randomly from a seed with per-`(node, t)` RNG streams, which
//! makes generated plans independent of enumeration order too.

use crate::rng::Rng;
use crate::{Error, Result};

/// Multiply node `node`'s compute time by `factor` for iterations
/// `from_t..=to_t` (a slow machine, a noisy neighbour, a GC pause).
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerRule {
    pub node: usize,
    pub from_t: u64,
    pub to_t: u64,
    pub factor: f64,
}

/// Node `node` crashes when it is about to start iteration `at_t`; the
/// cluster rolls back to the last checkpoint and restarts. Each rule
/// fires exactly once.
#[derive(Clone, Debug, PartialEq)]
pub struct CrashRule {
    pub node: usize,
    pub at_t: u64,
}

/// The first `count` transmission attempts of the ring message node
/// `from` produces at iteration `produced_at` are lost (the sender
/// retries after a timeout).
#[derive(Clone, Debug, PartialEq)]
pub struct DropRule {
    pub from: usize,
    pub produced_at: u64,
    pub count: u32,
}

/// The ring message node `from` produces at iteration `produced_at` is
/// delivered `extra_s` virtual seconds late.
#[derive(Clone, Debug, PartialEq)]
pub struct DelayRule {
    pub from: usize,
    pub produced_at: u64,
    pub extra_s: f64,
}

/// A full deterministic failure schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub stragglers: Vec<StragglerRule>,
    pub crashes: Vec<CrashRule>,
    pub drops: Vec<DropRule>,
    pub delays: Vec<DelayRule>,
}

/// Per-(node, iteration) probabilities used by [`FaultPlan::seeded`].
#[derive(Clone, Copy, Debug)]
pub struct FaultRates {
    /// P(a straggler window starts here); the window lasts `straggler_iters`.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
    pub straggler_iters: u64,
    /// P(the node crashes when starting this iteration).
    pub crash_prob: f64,
    /// P(the message produced here is dropped once).
    pub drop_prob: f64,
    /// P(the message produced here is delayed by `extra_delay_s`).
    pub delay_prob: f64,
    pub extra_delay_s: f64,
}

impl Default for FaultRates {
    fn default() -> Self {
        FaultRates {
            straggler_prob: 0.02,
            straggler_factor: 4.0,
            straggler_iters: 3,
            crash_prob: 0.005,
            drop_prob: 0.01,
            delay_prob: 0.02,
            extra_delay_s: 2e-3,
        }
    }
}

impl FaultPlan {
    /// The no-fault plan.
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.crashes.is_empty()
            && self.drops.is_empty()
            && self.delays.is_empty()
    }

    /// Derive a plan pseudo-randomly from `seed`. Each `(node, t)` cell
    /// gets its own RNG stream with a fixed draw order (straggler,
    /// crash, drop, delay), so the plan is a pure function of
    /// `(seed, b, t_total, rates)`.
    pub fn seeded(seed: u64, b: usize, t_total: u64, rates: &FaultRates) -> Self {
        let mut plan = FaultPlan::default();
        for node in 0..b {
            for t in 1..=t_total {
                let mut rng = Rng::derive(seed, &[0xfa_0175, node as u64, t]);
                if rng.next_f64() < rates.straggler_prob {
                    plan.stragglers.push(StragglerRule {
                        node,
                        from_t: t,
                        to_t: t + rates.straggler_iters.saturating_sub(1),
                        factor: rates.straggler_factor,
                    });
                }
                if rng.next_f64() < rates.crash_prob {
                    plan.crashes.push(CrashRule { node, at_t: t });
                }
                if rng.next_f64() < rates.drop_prob {
                    plan.drops.push(DropRule { from: node, produced_at: t, count: 1 });
                }
                if rng.next_f64() < rates.delay_prob {
                    plan.delays.push(DelayRule {
                        from: node,
                        produced_at: t,
                        extra_s: rates.extra_delay_s,
                    });
                }
            }
        }
        plan
    }

    /// Reject plans that reference nodes outside `0..b` or carry
    /// non-physical parameters — with messages that say which rule and
    /// what to fix, so a bad plan never reaches the event loop.
    pub fn validate(&self, b: usize) -> Result<()> {
        let node_err = |kind: &str, node: usize| {
            Error::Config(format!(
                "FaultPlan {kind} rule references node {node}, but the simulated cluster \
                 has only {b} nodes (valid indices 0..{b}); fix the rule or raise B"
            ))
        };
        for r in &self.stragglers {
            if r.node >= b {
                return Err(node_err("straggler", r.node));
            }
            if !(r.factor > 0.0 && r.factor.is_finite()) {
                return Err(Error::Config(format!(
                    "FaultPlan straggler factor must be positive and finite, got {}",
                    r.factor
                )));
            }
            if r.from_t == 0 || r.to_t < r.from_t {
                return Err(Error::Config(format!(
                    "FaultPlan straggler window [{}, {}] is invalid (iterations are \
                     1-based and the window must be non-empty)",
                    r.from_t, r.to_t
                )));
            }
        }
        for r in &self.crashes {
            if r.node >= b {
                return Err(node_err("crash", r.node));
            }
            if r.at_t == 0 {
                return Err(Error::Config(
                    "FaultPlan crash at iteration 0 is invalid (iterations are 1-based)"
                        .into(),
                ));
            }
        }
        for r in &self.drops {
            if r.from >= b {
                return Err(node_err("drop", r.from));
            }
        }
        for r in &self.delays {
            if r.from >= b {
                return Err(node_err("delay", r.from));
            }
            if !(r.extra_s >= 0.0 && r.extra_s.is_finite()) {
                return Err(Error::Config(format!(
                    "FaultPlan delay extra_s must be >= 0 and finite, got {}",
                    r.extra_s
                )));
            }
        }
        Ok(())
    }

    /// Compute-time multiplier for node `node` at iteration `t`
    /// (overlapping windows compound).
    pub fn slowdown(&self, node: usize, t: u64) -> f64 {
        self.stragglers
            .iter()
            .filter(|r| r.node == node && (r.from_t..=r.to_t).contains(&t))
            .map(|r| r.factor)
            .product()
    }

    /// How many transmission attempts of `(from, produced_at)`'s
    /// message are lost.
    pub fn drop_count(&self, from: usize, produced_at: u64) -> u32 {
        self.drops
            .iter()
            .filter(|r| r.from == from && r.produced_at == produced_at)
            .map(|r| r.count)
            .sum()
    }

    /// Extra delivery delay for `(from, produced_at)`'s message.
    pub fn extra_delay(&self, from: usize, produced_at: u64) -> f64 {
        self.delays
            .iter()
            .filter(|r| r.from == from && r.produced_at == produced_at)
            .map(|r| r.extra_s)
            .sum()
    }

    /// Whether node `node` is scheduled to crash when starting `t`.
    pub fn crash_at(&self, node: usize, t: u64) -> bool {
        self.crashes.iter().any(|r| r.node == node && r.at_t == t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        assert!(p.validate(4).is_ok());
        assert_eq!(p.slowdown(0, 1), 1.0);
        assert_eq!(p.drop_count(0, 1), 0);
        assert_eq!(p.extra_delay(0, 1), 0.0);
        assert!(!p.crash_at(0, 1));
    }

    #[test]
    fn seeded_is_deterministic() {
        let rates = FaultRates { crash_prob: 0.1, drop_prob: 0.2, ..Default::default() };
        let a = FaultPlan::seeded(99, 4, 50, &rates);
        let b = FaultPlan::seeded(99, 4, 50, &rates);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.validate(4).is_ok());
        let c = FaultPlan::seeded(100, 4, 50, &rates);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn rule_lookups() {
        let p = FaultPlan {
            stragglers: vec![StragglerRule { node: 1, from_t: 5, to_t: 7, factor: 3.0 }],
            crashes: vec![CrashRule { node: 2, at_t: 9 }],
            drops: vec![
                DropRule { from: 0, produced_at: 4, count: 2 },
                DropRule { from: 0, produced_at: 4, count: 1 },
            ],
            delays: vec![DelayRule { from: 3, produced_at: 2, extra_s: 0.5 }],
        };
        assert!(p.validate(4).is_ok());
        assert_eq!(p.slowdown(1, 5), 3.0);
        assert_eq!(p.slowdown(1, 8), 1.0);
        assert_eq!(p.slowdown(0, 5), 1.0);
        assert_eq!(p.drop_count(0, 4), 3);
        assert_eq!(p.extra_delay(3, 2), 0.5);
        assert!(p.crash_at(2, 9));
        assert!(!p.crash_at(2, 8));
    }

    #[test]
    fn validate_rejects_bad_nodes_with_actionable_message() {
        let p = FaultPlan {
            crashes: vec![CrashRule { node: 7, at_t: 3 }],
            ..Default::default()
        };
        let msg = format!("{}", p.validate(4).unwrap_err());
        assert!(msg.contains("node 7"), "{msg}");
        assert!(msg.contains("only 4 nodes"), "{msg}");

        let p = FaultPlan {
            stragglers: vec![StragglerRule { node: 0, from_t: 3, to_t: 2, factor: 2.0 }],
            ..Default::default()
        };
        assert!(p.validate(4).is_err());

        let p = FaultPlan {
            delays: vec![DelayRule { from: 0, produced_at: 1, extra_s: f64::NAN }],
            ..Default::default()
        };
        assert!(p.validate(4).is_err());
    }
}
