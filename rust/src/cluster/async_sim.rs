//! Event-driven asynchronous PSGLD executor with fault injection.
//!
//! Each of the `B` logical nodes advances independently through the
//! iterations, blocked only by the bounded-staleness rule: node `i` may
//! start iteration `t` as soon as its cached copy of the `H` stripe it
//! needs (`perm_t[i]`) is at most `tau` iterations stale; past the
//! bound it stalls until a fresher ring hand-off arrives. Staleness is
//! *content lineage*, not recency: each cached copy counts the block
//! updates baked into it, executing on a copy deepens its lineage by
//! one, and staleness is how many updates short of the chain front the
//! consumed copy was — so reusing a lap-old copy accrues a whole lap of
//! staleness every lap, rather than resetting to fresh. A [`FaultPlan`]
//! injects straggler slowdowns, crashes (with coordinated rollback to
//! the last consistent checkpoint) and ring-message drops/delays, all
//! keyed by logical coordinates so every run replays exactly.
//!
//! ## Determinism
//!
//! The chain is a function of `(seed, tau, plan)` only:
//!
//! * parts come from the stateless [`part_at_iter`] fed by
//!   `Rng::derive(seed, [t, 0xcafe])` — the same stream the synchronous
//!   simulator consumes;
//! * per-block noise comes from `Rng::derive(seed, [t, block])` inside
//!   the shared [`sparse_block_langevin`] body;
//! * event-queue tie-breaking ([`TieBreak`]) orders simultaneous events
//!   but can never touch the chain — pinned by `tests/fault_injection.rs`.
//!
//! With `tau = 0` and an empty plan every node consumes exactly-fresh
//! stripes, so the executed updates are identical to the synchronous
//! simulator's and the chain is bitwise equal (also asserted in tests).
//!
//! ## Consistent snapshots without barriers
//!
//! Updates apply at iteration *start*; the [`Slot`] for iteration `t`
//! collects each node's updated `W`/`H` stripes as they execute and
//! completes when all `B` nodes have *finished* `t`. Completed slots
//! are exact global states — they feed the monitor trace, periodic
//! checkpoints (through [`Checkpoint`]), and crash recovery.

use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};

use crate::config::{AsyncClusterConfig, RunConfig};
use crate::coordinator::Checkpoint;
use crate::data::sparse::{BlockedSparse, Csr};
use crate::linalg::Mat;
use crate::metrics::{NodeStats, Trace};
use crate::model::NmfModel;
use crate::obs::{self, Counter, ObsLevel, VtEvent};
use crate::partition::{part_at_iter, GridPartition, Part};
use crate::rng::Rng;
use crate::samplers::{sparse_block_langevin, FactorState};
use crate::util::parallel::ScratchArena;
use crate::{Error, Result};

use super::event::{EventKind, EventQueue, Msg, TieBreak};
use super::fault::FaultPlan;
use super::staleness::StalenessLedger;
use super::{ComputeModel, NetworkModel};

/// Result of an asynchronous fault-injected run.
#[derive(Clone, Debug)]
pub struct AsyncSimReport {
    /// Virtual time at which the last node finished.
    pub virtual_seconds: f64,
    /// Summed per-node compute time (stragglers included).
    pub busy_seconds: f64,
    /// Summed per-node time blocked on the staleness bound.
    pub stall_seconds: f64,
    /// Chain length delivered (`run.t_total`).
    pub iterations: u64,
    /// Block updates actually executed, re-execution after rollback
    /// included (`>= iterations * B` when crashes occurred).
    pub executed_iterations: u64,
    /// Crash→rollback→restart cycles.
    pub recoveries: u64,
    /// Consistent checkpoints taken.
    pub checkpoints_taken: u64,
    /// Ring messages produced (logical sends, not attempts).
    pub messages_sent: u64,
    /// Transmission attempts the network dropped.
    pub messages_dropped: u64,
    /// Retransmissions after timeouts.
    pub retries: u64,
    /// Monitor trace (virtual-time x-axis, per-node counters attached).
    pub trace: Trace,
    /// Final factor state (the consistent iteration-`t_total` snapshot).
    pub state: FactorState,
    /// Full staleness log of the surviving (post-rollback) chain.
    pub ledger: StalenessLedger,
    /// Virtual-time timeline slices (compute / stall / comms /
    /// rollback / checkpoint per node), collected only when
    /// `PALLAS_OBS=full`; feed to [`crate::obs::write_chrome_trace`].
    pub vt_events: Vec<VtEvent>,
}

/// A node's cached copy of one `H` column-stripe.
#[derive(Clone, Debug)]
struct CacheEntry {
    /// Lineage depth: how many block updates are baked into `data`.
    /// Bumped by one on every execution against this copy; replaced by
    /// max-merge when a ring message with a deeper lineage arrives.
    /// Staleness of a consumption at iteration `t` is `(t-1) - version`
    /// — how many updates short of the chain front the copy is.
    version: u64,
    /// `cols × K`, row-major.
    data: Vec<f32>,
}

#[derive(Clone, Copy, Debug)]
struct Stall {
    since: f64,
    block: usize,
}

#[derive(Clone, Copy, Debug)]
struct NodeRt {
    /// Next iteration this node will start (1-based).
    next_t: u64,
    done: bool,
    stalled: Option<Stall>,
}

/// In-flight consistent snapshot of one iteration.
struct Slot {
    w: Mat,
    ht: Mat,
    finished: Vec<bool>,
    finished_count: usize,
    /// Virtual time the slowest contributor finished.
    time: f64,
}

impl Slot {
    fn new(rows: usize, cols: usize, k: usize, b: usize) -> Self {
        Slot {
            w: Mat::zeros(rows, k),
            ht: Mat::zeros(cols, k),
            finished: vec![false; b],
            finished_count: 0,
            time: 0.0,
        }
    }
}

struct AsyncSim<'a> {
    model: &'a NmfModel,
    run: &'a RunConfig,
    cfg: &'a AsyncClusterConfig,
    plan: &'a FaultPlan,
    net: &'a NetworkModel,
    compute: &'a ComputeModel,
    blocked: BlockedSparse,
    grid: GridPartition,
    seed: u64,
    b: usize,
    k: usize,
    queue: EventQueue,
    nodes: Vec<NodeRt>,
    /// `cache[node][stripe]` — each node's view of every `H` stripe.
    cache: Vec<Vec<CacheEntry>>,
    /// Global `W` (stripe `i` written only by node `i`).
    w: Mat,
    /// Per-node gradient accumulators.
    scratch: Vec<(Vec<f32>, Vec<f32>)>,
    arena: ScratchArena,
    part_buf: Part,
    /// In-flight iteration snapshots. Bounded: lineage staleness grows
    /// with lead, so the `tau` bound stalls any node more than
    /// ~`B * (tau + 1)` iterations ahead of the slowest one, and at
    /// most that many slots are ever open.
    slots: BTreeMap<u64, Slot>,
    trace: Trace,
    ledger: StalenessLedger,
    /// Last consistent checkpoint (iteration, state); iteration 0 is the
    /// prior draw.
    last_ckpt: (u64, FactorState),
    ckpt_path: Option<PathBuf>,
    ckpt_on_disk: bool,
    /// Crash rules that already fired (each fires once).
    consumed_crashes: HashSet<(usize, u64)>,
    stats: Vec<NodeStats>,
    done_count: usize,
    now: f64,
    busy_s: f64,
    final_state: Option<FactorState>,
    checkpoints_taken: u64,
    recoveries: u64,
    executed: u64,
    /// Sampled once at construction: collect virtual-time slices?
    /// (Never re-read mid-run, so a level flip cannot skew a run.)
    vt_on: bool,
    vt: Vec<VtEvent>,
}

impl AsyncSim<'_> {
    /// Overwrite `part_buf` with the part of iteration `t`.
    fn set_part(&mut self, t: u64) {
        let mut rng = Rng::derive(self.seed, &[t, 0xcafe]);
        part_at_iter(self.run.schedule, self.b, t, &mut rng, &mut self.part_buf);
    }

    /// Start node `i`'s next iteration if the staleness bound allows it;
    /// stall otherwise. Fires pending crash rules first.
    fn try_start(&mut self, i: usize) -> Result<()> {
        if self.nodes[i].done || self.nodes[i].stalled.is_some() {
            return Ok(());
        }
        let t = self.nodes[i].next_t;
        if t > self.run.t_total {
            self.nodes[i].done = true;
            self.done_count += 1;
            return Ok(());
        }
        if self.plan.crash_at(i, t) && self.consumed_crashes.insert((i, t)) {
            return self.rollback(i);
        }
        self.set_part(t);
        let j = self.part_buf.perm[i];
        let staleness = (t - 1).saturating_sub(self.cache[i][j].version);
        if staleness > self.cfg.tau {
            self.nodes[i].stalled = Some(Stall { since: self.now, block: j });
            self.stats[i].stalls += 1;
            obs::counter_add(Counter::Stalls, 1);
            return Ok(());
        }
        self.ledger.record(i, t, staleness)?;
        self.exec_update(i, t, j, staleness);
        Ok(())
    }

    /// Apply node `i`'s block update for iteration `t` (stripe pair
    /// `(i, j)`, executing at `staleness`), capture the result into
    /// iteration `t`'s slot, and schedule the compute-phase finish.
    fn exec_update(&mut self, i: usize, t: u64, j: usize, staleness: u64) {
        let k = self.k;
        let rows = self.grid.row_range(i);
        let cols = self.grid.col_range(j);
        let (m, n) = (rows.len(), cols.len());
        let eps = self.run.step.eps(t) as f32;
        let scale = self.blocked.scale(&self.part_buf);
        // An async node has no consistent global state to rescan, so the
        // sparse nonneg fast path is decided by the mirror flag alone
        // (for mirror models this matches the synchronous executors'
        // nonneg_hint exactly — the bitwise-equality tests rely on it).
        let nonneg = self.model.mirror;
        let (rows_total, cols_total, b) = (self.grid.rows(), self.grid.cols(), self.b);

        let w_slice = &mut self.w.as_mut_slice()[rows.start * k..rows.end * k];
        let entry = &mut self.cache[i][j];
        let sb = &mut self.scratch[i];
        sparse_block_langevin(
            w_slice,
            &mut entry.data,
            k,
            self.blocked.block(i, j),
            self.model,
            nonneg,
            eps,
            scale,
            self.seed,
            t,
            i as u64,
            &mut sb.0[..m * k],
            &mut sb.1[..n * k],
            &mut self.arena,
        );
        // Content lineage: exactly one more update is baked into this
        // copy than before — stale content does NOT become fresh by
        // being updated. A lap-old reuse therefore stays a lap behind,
        // staleness accumulates across stale executions, and because a
        // copy that keeps bypassing the slowest producer keeps losing
        // lineage, the tau bound also caps how far fast nodes can run
        // ahead (and with it the number of in-flight `slots`).
        entry.version += 1;

        let slot = self
            .slots
            .entry(t)
            .or_insert_with(|| Slot::new(rows_total, cols_total, k, b));
        slot.w.as_mut_slice()[rows.start * k..rows.end * k].copy_from_slice(w_slice);
        slot.ht.as_mut_slice()[cols.start * k..cols.end * k].copy_from_slice(&entry.data);

        self.executed += 1;
        self.stats[i].iterations += 1;
        let base = self
            .compute
            .block_time_s(self.blocked.block(i, j).nnz(), (m + n) * k);
        let dur = base * self.plan.slowdown(i, t);
        self.busy_s += dur;
        crate::monitor::observe_node_exec(i, t, staleness, self.cfg.tau, dur);
        self.queue.push(self.now + dur, EventKind::NodeFinish { node: i, t });
        if self.vt_on {
            self.vt.push(VtEvent {
                name: "compute",
                cat: "kernel",
                track: i as u32,
                start_s: self.now,
                dur_s: dur,
            });
        }
    }

    /// Node `i` finished the compute phase of iteration `t`: complete
    /// the slot bookkeeping, hand the updated stripe to its next
    /// consumer, and move on.
    fn on_finish(
        &mut self,
        i: usize,
        t: u64,
        monitor: &mut dyn FnMut(&FactorState) -> f64,
    ) -> Result<()> {
        if let Some(slot) = self.slots.get_mut(&t) {
            if !slot.finished[i] {
                slot.finished[i] = true;
                slot.finished_count += 1;
                slot.time = slot.time.max(self.now);
            }
        }
        self.finalize_ready_slots(monitor)?;

        self.set_part(t);
        let j = self.part_buf.perm[i];
        if t < self.run.t_total {
            // the node that consumes stripe j at t+1 (ring neighbour
            // under the cyclic schedule)
            self.set_part(t + 1);
            let nb = self
                .part_buf
                .perm
                .iter()
                .position(|&x| x == j)
                .expect("part perm is a bijection");
            if nb != i {
                let entry = &self.cache[i][j];
                let msg = Msg {
                    from: i,
                    to: nb,
                    block: j,
                    version: entry.version,
                    produced_at: t,
                    attempt: 0,
                    data: entry.data.clone(),
                };
                self.stats[i].msgs_sent += 1;
                obs::counter_add(Counter::MsgsSent, 1);
                crate::monitor::observe_node_msgs(i, t, 1, 0);
                self.send(msg)?;
            }
        }
        self.nodes[i].next_t = t + 1;
        self.try_start(i)
    }

    /// Transmit (or drop-and-arm-retry) a ring message at `self.now`.
    fn send(&mut self, mut msg: Msg) -> Result<()> {
        let drops = self.plan.drop_count(msg.from, msg.produced_at);
        if msg.attempt < drops {
            self.stats[msg.from].msgs_dropped += 1;
            obs::counter_add(Counter::MsgsDropped, 1);
            crate::monitor::observe_node_msgs(msg.from, msg.produced_at, 0, 1);
            if self.vt_on {
                self.vt.push(VtEvent {
                    name: "msg_dropped",
                    cat: "comms",
                    track: msg.from as u32,
                    start_s: self.now,
                    dur_s: 0.0,
                });
            }
            if msg.attempt >= self.cfg.max_retries {
                return Err(Error::Runtime(format!(
                    "ring message from node {} (iteration {}) was dropped {} times, \
                     exceeding max_retries={}; failing loudly instead of hanging the \
                     event loop — raise max_retries or fix the FaultPlan",
                    msg.from,
                    msg.produced_at,
                    msg.attempt + 1,
                    self.cfg.max_retries
                )));
            }
            let backoff = self.cfg.msg_timeout_s * self.cfg.retry_backoff.powi(msg.attempt as i32);
            msg.attempt += 1;
            self.queue.push(self.now + backoff, EventKind::RetryTimer(msg));
            return Ok(());
        }
        let bytes = msg.data.len() * std::mem::size_of::<f32>();
        let latency = self.net.ring_exchange_s(self.b, bytes)
            + self.plan.extra_delay(msg.from, msg.produced_at);
        let from = msg.from;
        self.queue.push(self.now + latency, EventKind::MsgArrive(msg));
        if self.vt_on {
            self.vt.push(VtEvent {
                name: "msg",
                cat: "comms",
                track: from as u32,
                start_s: self.now,
                dur_s: latency,
            });
        }
        Ok(())
    }

    /// Deliver a ring message: the deeper lineage wins the cache (a
    /// late message from a slow producer whose updates were already
    /// bypassed is superseded and dropped — that divergence is the
    /// price of proceeding stale), then wake the receiver if it was
    /// stalled on this stripe and the merged copy satisfies the bound.
    fn on_msg(&mut self, msg: Msg) -> Result<()> {
        let entry = &mut self.cache[msg.to][msg.block];
        if msg.version > entry.version {
            entry.version = msg.version;
            entry.data.clear();
            entry.data.extend_from_slice(&msg.data);
        }
        if let Some(st) = self.nodes[msg.to].stalled {
            if st.block == msg.block {
                let t = self.nodes[msg.to].next_t;
                let staleness = (t - 1).saturating_sub(self.cache[msg.to][msg.block].version);
                if staleness <= self.cfg.tau {
                    self.stats[msg.to].stall_seconds += self.now - st.since;
                    crate::monitor::observe_node_stall(msg.to, self.now - st.since);
                    if self.vt_on {
                        self.vt.push(VtEvent {
                            name: "stall",
                            cat: "stall",
                            track: msg.to as u32,
                            start_s: st.since,
                            dur_s: self.now - st.since,
                        });
                    }
                    self.nodes[msg.to].stalled = None;
                    self.try_start(msg.to)?;
                }
            }
        }
        Ok(())
    }

    /// Coordinated crash recovery: every node rolls back to the last
    /// consistent checkpoint `c`, in-flight work is discarded, and the
    /// cluster restarts at `c + 1` after `restart_delay_s`.
    fn rollback(&mut self, crashed: usize) -> Result<()> {
        self.recoveries += 1;
        self.stats[crashed].recoveries += 1;
        obs::counter_add(Counter::Rollbacks, 1);
        if self.vt_on {
            self.vt.push(VtEvent {
                name: "rollback",
                cat: "rollback",
                track: crashed as u32,
                start_s: self.now,
                dur_s: self.cfg.restart_delay_s,
            });
        }
        // Restore through the on-disk path when one exists (exercising
        // Checkpoint::load), else from the in-memory snapshot.
        let (c, state) = if self.ckpt_on_disk {
            let path = self.ckpt_path.as_ref().expect("ckpt_on_disk implies a path");
            let ck = Checkpoint::load(path)?;
            (ck.iteration, ck.state)
        } else {
            (self.last_ckpt.0, self.last_ckpt.1.clone())
        };
        self.queue.clear();
        self.slots.clear();
        self.ledger.truncate_after(c);
        while self.trace.iters.last().is_some_and(|&it| it > c) {
            self.trace.iters.pop();
            self.trace.seconds.pop();
            self.trace.values.pop();
        }
        self.w = state.w.clone();
        let k = self.k;
        let b = self.b;
        for row in &mut self.cache {
            for j in 0..b {
                let cols = self.grid.col_range(j);
                let entry = &mut row[j];
                entry.version = c;
                entry.data.clear();
                entry
                    .data
                    .extend_from_slice(&state.ht.as_slice()[cols.start * k..cols.end * k]);
            }
        }
        for (i, node) in self.nodes.iter_mut().enumerate() {
            // A stall interrupted by the crash still happened: credit
            // the accrued wait before resetting, or stall_seconds
            // silently undercounts in faulty runs.
            if let Some(st) = node.stalled {
                self.stats[i].stall_seconds += self.now - st.since;
                crate::monitor::observe_node_stall(i, self.now - st.since);
                if self.vt_on {
                    self.vt.push(VtEvent {
                        name: "stall",
                        cat: "stall",
                        track: i as u32,
                        start_s: st.since,
                        dur_s: self.now - st.since,
                    });
                }
            }
            if node.done {
                self.done_count -= 1;
            }
            *node = NodeRt { next_t: c + 1, done: false, stalled: None };
        }
        self.queue
            .push(self.now + self.cfg.restart_delay_s, EventKind::RestartDone);
        Ok(())
    }

    /// All nodes are back up after a rollback: start them. If one of
    /// the restarts immediately crashes again (a crash rule at `c + 1`),
    /// the rollback has already reset everything — stop fanning out.
    fn on_restart(&mut self) -> Result<()> {
        let rec = self.recoveries;
        for i in 0..self.b {
            self.try_start(i)?;
            if self.recoveries != rec {
                break;
            }
        }
        Ok(())
    }

    /// Finalize completed iteration snapshots in order: monitor trace,
    /// periodic checkpoints, final state. Completion is monotone in `t`
    /// (slot `t+1` cannot complete before slot `t`), so draining from
    /// the front of the map is exact.
    fn finalize_ready_slots(
        &mut self,
        monitor: &mut dyn FnMut(&FactorState) -> f64,
    ) -> Result<()> {
        loop {
            let ready = match self.slots.iter().next() {
                Some((&t, slot)) if slot.finished_count == self.b => t,
                _ => return Ok(()),
            };
            let slot = self.slots.remove(&ready).expect("slot present");
            let t = ready;
            let state = FactorState { w: slot.w, ht: slot.ht };
            if t % self.run.monitor_every == 0 || t == self.run.t_total {
                self.trace.push(t, slot.time, monitor(&state));
            }
            if self.cfg.checkpoint_every > 0 && t % self.cfg.checkpoint_every == 0 {
                if let Some(path) = &self.ckpt_path {
                    Checkpoint::new(t, self.seed, &state).save(path)?;
                    self.ckpt_on_disk = true;
                }
                self.last_ckpt = (t, state.clone());
                self.checkpoints_taken += 1;
                obs::counter_add(Counter::Checkpoints, 1);
                if self.vt_on {
                    self.vt.push(VtEvent {
                        name: "checkpoint",
                        cat: "checkpoint",
                        track: 0,
                        start_s: slot.time,
                        dur_s: 0.0,
                    });
                }
            }
            if t == self.run.t_total {
                self.final_state = Some(state);
            }
        }
    }
}

/// Asynchronous distributed PSGLD over a sparse matrix with bounded
/// staleness and fault injection. With `cfg.tau == 0` and an empty
/// `plan`, the chain is bitwise identical to
/// [`super::psgld_distributed_full`] for mirror models.
#[allow(clippy::too_many_arguments)]
pub fn psgld_distributed_async(
    v: &Csr,
    model: &NmfModel,
    b: usize,
    run: &RunConfig,
    seed: u64,
    net: &NetworkModel,
    compute: &ComputeModel,
    cfg: &AsyncClusterConfig,
    plan: &FaultPlan,
    tie: TieBreak,
    mut monitor: impl FnMut(&FactorState) -> f64,
) -> Result<AsyncSimReport> {
    run.validate()?;
    cfg.validate()?;
    plan.validate(b)?;
    let blocked = BlockedSparse::from_csr(v, b)?;
    let grid = blocked.grid().clone();
    let k = model.k;

    // Same init stream as every other executor.
    let mut rng = Rng::derive(seed, &[0x9516_1d]);
    let init = FactorState::from_prior(model, grid.rows(), grid.cols(), &mut rng);

    let cache: Vec<Vec<CacheEntry>> = (0..b)
        .map(|_| {
            (0..b)
                .map(|j| {
                    let cols = grid.col_range(j);
                    CacheEntry {
                        version: 0,
                        data: init.ht.as_slice()[cols.start * k..cols.end * k].to_vec(),
                    }
                })
                .collect()
        })
        .collect();
    let max_n = (0..b).map(|bj| grid.col_range(bj).len()).max().unwrap_or(0);
    let scratch: Vec<(Vec<f32>, Vec<f32>)> = (0..b)
        .map(|bi| (vec![0f32; grid.row_range(bi).len() * k], vec![0f32; max_n * k]))
        .collect();
    let ckpt_path = cfg
        .checkpoint_dir
        .as_ref()
        .map(|d| Checkpoint::latest_path(Path::new(d)));

    let mut trace = Trace::new("psgld_async");
    trace.push(0, 0.0, monitor(&init));

    let mut sim = AsyncSim {
        model,
        run,
        cfg,
        plan,
        net,
        compute,
        blocked,
        grid,
        seed,
        b,
        k,
        queue: EventQueue::new(tie),
        nodes: vec![NodeRt { next_t: 1, done: false, stalled: None }; b],
        cache,
        w: init.w.clone(),
        scratch,
        arena: ScratchArena::new(),
        part_buf: Part::identity(b),
        slots: BTreeMap::new(),
        trace,
        ledger: StalenessLedger::new(cfg.tau),
        last_ckpt: (0, init),
        ckpt_path,
        ckpt_on_disk: false,
        consumed_crashes: HashSet::new(),
        stats: (0..b)
            .map(|node| NodeStats { node, ..Default::default() })
            .collect(),
        done_count: 0,
        now: 0.0,
        busy_s: 0.0,
        final_state: None,
        checkpoints_taken: 0,
        recoveries: 0,
        executed: 0,
        vt_on: obs::level() == ObsLevel::Full,
        vt: Vec::new(),
    };

    // Kick off every node (guarding against an immediate crash rule at
    // t = 1 resetting the cluster mid-fan-out).
    let rec = sim.recoveries;
    for i in 0..b {
        sim.try_start(i)?;
        if sim.recoveries != rec {
            break;
        }
    }

    // Generous livelock backstop: a healthy run fires O(B) events per
    // iteration; crashes re-execute at most the checkpoint interval.
    let budget = 10_000 + 200 * b as u64 * run.t_total.max(1);
    let mut events = 0u64;
    while sim.done_count < sim.b {
        let (time, kind) = sim.queue.pop().ok_or_else(|| {
            Error::Runtime(
                "async simulator deadlocked: event queue drained with unfinished nodes \
                 (a node is stalled past tau with no message in flight) — check the \
                 FaultPlan and tau"
                    .into(),
            )
        })?;
        sim.now = sim.now.max(time);
        events += 1;
        if events > budget {
            return Err(Error::Runtime(format!(
                "async simulator exceeded its event budget ({budget}); likely a \
                 retry/crash livelock — check the FaultPlan"
            )));
        }
        match kind {
            EventKind::NodeFinish { node, t } => sim.on_finish(node, t, &mut monitor)?,
            EventKind::MsgArrive(msg) => sim.on_msg(msg)?,
            EventKind::RetryTimer(msg) => {
                sim.stats[msg.from].retries += 1;
                obs::counter_add(Counter::Retries, 1);
                if sim.vt_on {
                    sim.vt.push(VtEvent {
                        name: "retry",
                        cat: "comms",
                        track: msg.from as u32,
                        start_s: sim.now,
                        dur_s: 0.0,
                    });
                }
                sim.send(msg)?;
            }
            EventKind::RestartDone => sim.on_restart()?,
        }
    }

    let state = sim.final_state.take().ok_or_else(|| {
        Error::Runtime("async simulator finished without a final snapshot — executor bug".into())
    })?;
    for (node, (mx, mean, _)) in sim.ledger.per_node(b).into_iter().enumerate() {
        sim.stats[node].max_staleness = mx;
        sim.stats[node].mean_staleness = mean;
    }
    let stall_seconds: f64 = sim.stats.iter().map(|s| s.stall_seconds).sum();
    let messages_sent: u64 = sim.stats.iter().map(|s| s.msgs_sent).sum();
    let messages_dropped: u64 = sim.stats.iter().map(|s| s.msgs_dropped).sum();
    let retries: u64 = sim.stats.iter().map(|s| s.retries).sum();
    sim.trace.node_stats = sim.stats;

    Ok(AsyncSimReport {
        virtual_seconds: sim.now,
        busy_seconds: sim.busy_s,
        stall_seconds,
        iterations: run.t_total,
        executed_iterations: sim.executed,
        recoveries: sim.recoveries,
        checkpoints_taken: sim.checkpoints_taken,
        messages_sent,
        messages_dropped,
        retries,
        trace: sim.trace,
        state,
        ledger: sim.ledger,
        vt_events: sim.vt,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StepSchedule;
    use crate::data::movielens;

    fn quick_setup() -> (Csr, NmfModel, RunConfig) {
        let csr = movielens::movielens_like_dims(32, 40, 400, 3, 7);
        let model = NmfModel::poisson(3).with_priors(2.0, 2.0);
        let run = RunConfig::quick(24).with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        (csr, model, run)
    }

    #[test]
    fn healthy_async_run_completes() {
        let (csr, model, run) = quick_setup();
        let rep = psgld_distributed_async(
            &csr,
            &model,
            4,
            &run,
            11,
            &NetworkModel::paper_cluster(),
            &ComputeModel::paper_node(),
            &AsyncClusterConfig::default(),
            &FaultPlan::empty(),
            TieBreak::Fifo,
            |_| 0.0,
        )
        .unwrap();
        assert_eq!(rep.iterations, 24);
        assert_eq!(rep.executed_iterations, 24 * 4);
        assert_eq!(rep.recoveries, 0);
        assert_eq!(rep.messages_dropped, 0);
        assert!(rep.virtual_seconds > 0.0);
        assert!(rep.state.w.as_slice().iter().all(|x| x.is_finite()));
        // tau=0: every consumed block was exactly fresh
        assert_eq!(rep.ledger.max_staleness(), 0);
        assert_eq!(rep.trace.node_stats.len(), 4);
    }

    #[test]
    fn invalid_plan_rejected_before_the_loop() {
        let (csr, model, run) = quick_setup();
        let plan = FaultPlan {
            crashes: vec![super::super::fault::CrashRule { node: 99, at_t: 1 }],
            ..Default::default()
        };
        let err = psgld_distributed_async(
            &csr,
            &model,
            4,
            &run,
            11,
            &NetworkModel::paper_cluster(),
            &ComputeModel::paper_node(),
            &AsyncClusterConfig::default(),
            &plan,
            TieBreak::Fifo,
            |_| 0.0,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("node 99"));
    }

    #[test]
    fn permanent_drop_fails_loudly_not_hangs() {
        let (csr, model, run) = quick_setup();
        let cfg = AsyncClusterConfig { max_retries: 2, ..Default::default() };
        // drop far more times than max_retries allows
        let plan = FaultPlan {
            drops: vec![super::super::fault::DropRule { from: 0, produced_at: 1, count: 50 }],
            ..Default::default()
        };
        let err = psgld_distributed_async(
            &csr,
            &model,
            4,
            &run,
            11,
            &NetworkModel::paper_cluster(),
            &ComputeModel::paper_node(),
            &cfg,
            &plan,
            TieBreak::Fifo,
            |_| 0.0,
        )
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("max_retries"), "{msg}");
    }
}
