//! Staleness accounting for bounded-staleness PSGLD.
//!
//! Every block update in the async executor records how stale the `H`
//! stripe it consumed was: how many block updates short of the chain
//! front its content lineage ran (see `async_sim::CacheEntry`).
//! The ledger *enforces* the bound — recording a violation is an error,
//! not a statistic — so "staleness never exceeds `tau`" is checkable by
//! construction and asserted again from the outside by the tests.

use crate::{Error, Result};

/// One block update's staleness observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StaleRecord {
    /// Node that performed the update.
    pub node: usize,
    /// Iteration of the update (1-based).
    pub t: u64,
    /// How many iterations behind fresh the consumed `H` stripe was.
    pub staleness: u64,
}

/// Append-only log of staleness observations, truncated on rollback.
#[derive(Clone, Debug)]
pub struct StalenessLedger {
    tau: u64,
    records: Vec<StaleRecord>,
}

impl StalenessLedger {
    pub fn new(tau: u64) -> Self {
        StalenessLedger { tau, records: Vec::new() }
    }

    pub fn tau(&self) -> u64 {
        self.tau
    }

    /// Record one observation; refuses to log a bound violation (the
    /// executor must stall instead of proceeding past `tau`).
    pub fn record(&mut self, node: usize, t: u64, staleness: u64) -> Result<()> {
        if staleness > self.tau {
            return Err(Error::Runtime(format!(
                "staleness bound violated: node {node} at iteration {t} proceeded with an \
                 H block {staleness} iterations stale but tau={} — executor bug",
                self.tau
            )));
        }
        self.records.push(StaleRecord { node, t, staleness });
        Ok(())
    }

    /// Drop every record past iteration `c` (crash rollback).
    pub fn truncate_after(&mut self, c: u64) {
        self.records.retain(|r| r.t <= c);
    }

    pub fn records(&self) -> &[StaleRecord] {
        &self.records
    }

    pub fn max_staleness(&self) -> u64 {
        self.records.iter().map(|r| r.staleness).max().unwrap_or(0)
    }

    /// Fraction of updates that consumed a stale (staleness > 0) block.
    pub fn stale_fraction(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let stale = self.records.iter().filter(|r| r.staleness > 0).count();
        stale as f64 / self.records.len() as f64
    }

    /// Per-node `(max, mean, count)` staleness over `b` nodes.
    pub fn per_node(&self, b: usize) -> Vec<(u64, f64, u64)> {
        let mut max = vec![0u64; b];
        let mut sum = vec![0u64; b];
        let mut cnt = vec![0u64; b];
        for r in &self.records {
            max[r.node] = max[r.node].max(r.staleness);
            sum[r.node] += r.staleness;
            cnt[r.node] += 1;
        }
        (0..b)
            .map(|i| (max[i], sum[i] as f64 / cnt[i].max(1) as f64, cnt[i]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_within_bound() {
        let mut l = StalenessLedger::new(2);
        l.record(0, 1, 0).unwrap();
        l.record(1, 2, 2).unwrap();
        assert_eq!(l.records().len(), 2);
        assert_eq!(l.max_staleness(), 2);
        assert_eq!(l.stale_fraction(), 0.5);
    }

    #[test]
    fn rejects_bound_violation_loudly() {
        let mut l = StalenessLedger::new(1);
        let msg = format!("{}", l.record(3, 10, 2).unwrap_err());
        assert!(msg.contains("node 3"), "{msg}");
        assert!(msg.contains("tau=1"), "{msg}");
    }

    #[test]
    fn truncate_after_rollback() {
        let mut l = StalenessLedger::new(4);
        for t in 1..=10 {
            l.record(0, t, 0).unwrap();
        }
        l.truncate_after(6);
        assert_eq!(l.records().len(), 6);
        assert!(l.records().iter().all(|r| r.t <= 6));
    }

    #[test]
    fn per_node_summary() {
        let mut l = StalenessLedger::new(4);
        l.record(0, 1, 0).unwrap();
        l.record(0, 2, 4).unwrap();
        l.record(1, 1, 1).unwrap();
        let pn = l.per_node(3);
        assert_eq!(pn[0], (4, 2.0, 2));
        assert_eq!(pn[1], (1, 1.0, 1));
        assert_eq!(pn[2], (0, 0.0, 0));
    }
}
