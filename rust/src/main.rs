//! `psgld` — CLI launcher for the PSGLD reproduction.
//!
//! One subcommand per experiment in DESIGN.md §5 (clap is unavailable
//! offline, so argument parsing is hand-rolled; `psgld help` documents
//! everything).

use std::path::PathBuf;
use std::process::ExitCode;

use psgld::experiments::{ablations, fig2, fig3, fig5, fig6, ExpOptions};

const HELP: &str = "\
psgld — Parallel Stochastic Gradient MCMC for Matrix Factorisation
(Şimşekli et al., 2015 reproduction)

USAGE:
    psgld <COMMAND> [OPTIONS]

COMMANDS:
    quickstart        tiny end-to-end PSGLD run (native + HLO backends)
    fig2a             Poisson-NMF mixing + runtimes (Gibbs/LD/SGLD/PSGLD)
    fig2b             compound-Poisson mixing + runtimes (LD/SGLD/PSGLD)
    fig3              audio spectrogram decomposition (PSGLD/LD/Gibbs)
    fig5              MovieLens RMSE: PSGLD vs DSGD (sparse, B=15, K=50)
    fig6a             strong scaling on the simulated cluster (5..120 nodes)
    fig6b             weak scaling (data x4 & nodes x2 per step)
    comm              DSGLD-vs-PSGLD communication comparison (§1 claim)
    ablations         schedule / mirroring / B / backend ablations
    all               every experiment in sequence
    validate-trace PATH   schema-check a trace JSON written by --trace-out
    check-regression  compare fresh bench/health JSON against baselines:
                      --baseline PATH --current PATH [--tol-frac F]
                      (exit 1 when a metric drops below baseline*(1-F))
    help              this text

OPTIONS:
    --out DIR         output directory for CSVs        [results]
    --artifacts DIR   AOT artifact directory           [artifacts]
    --seed N          master RNG seed                  [2015]
    --iters N         override iteration count
    --full            paper-scale runs (hours, not minutes)
    --no-gibbs        skip the Gibbs comparator
    --trace-out PATH  write a Perfetto/Chrome trace-event JSON timeline
                      (implies PALLAS_OBS=full unless PALLAS_OBS is set)
    --metrics-addr A  serve OpenMetrics at http://A/metrics for the run
                      (implies PALLAS_OBS=counters unless PALLAS_OBS is set)

ENVIRONMENT:
    PALLAS_OBS        off | counters | full   instrumentation level [off]
    PALLAS_METRICS_ADDR   addr:port to serve OpenMetrics (same as --metrics-addr)
    PALLAS_LOG        off | error | warn | info | debug   log level [info]
    PALLAS_THREADS    worker pool width (0/1 = sequential)
    PALLAS_SIMD       scalar | avx2 | auto    kernel dispatch tier [auto]

EXAMPLES:
    psgld quickstart
    psgld fig2a --iters 1000
    psgld fig5 --full --out results/full
    PALLAS_OBS=full psgld fig5 --iters 30 --trace-out results/fig5_trace.json
    psgld fig5 --metrics-addr 127.0.0.1:9464   # curl http://127.0.0.1:9464/metrics
    psgld check-regression --baseline baselines --current results --tol-frac 0.2
";

fn parse_opts(args: &[String]) -> Result<ExpOptions, String> {
    let mut opts = ExpOptions::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--out" => {
                opts.outdir = PathBuf::from(
                    it.next().ok_or_else(|| "--out needs a value".to_string())?,
                )
            }
            "--artifacts" => {
                opts.artifacts = PathBuf::from(
                    it.next().ok_or_else(|| "--artifacts needs a value".to_string())?,
                )
            }
            "--seed" => {
                opts.seed = it
                    .next()
                    .ok_or_else(|| "--seed needs a value".to_string())?
                    .parse()
                    .map_err(|e| format!("bad --seed: {e}"))?
            }
            "--iters" => {
                opts.iters = Some(
                    it.next()
                        .ok_or_else(|| "--iters needs a value".to_string())?
                        .parse()
                        .map_err(|e| format!("bad --iters: {e}"))?,
                )
            }
            "--full" => opts.full = true,
            "--no-gibbs" => opts.gibbs = false,
            "--trace-out" => {
                opts.trace_out = Some(PathBuf::from(
                    it.next().ok_or_else(|| "--trace-out needs a value".to_string())?,
                ))
            }
            "--metrics-addr" => {
                opts.metrics_addr = Some(
                    it.next()
                        .ok_or_else(|| "--metrics-addr needs a value".to_string())?
                        .clone(),
                )
            }
            other => return Err(format!("unknown option '{other}'")),
        }
    }
    Ok(opts)
}

fn quickstart(opts: &ExpOptions) -> psgld::Result<()> {
    use psgld::config::{RunConfig, StepSchedule};
    use psgld::coordinator::HloPsgld;
    use psgld::data::synth;
    use psgld::model::NmfModel;
    use psgld::samplers::{run_sampler, Psgld};

    psgld::log_info!("PSGLD quickstart: 128x128 Poisson-NMF, K=16, B=4");
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, opts.seed);
    let t = opts.t(400, 2_000);
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

    let mut native = Psgld::new(&data.v, &model, 4, run.clone(), opts.seed);
    let res = run_sampler(&mut native, &run, |s| {
        model.loglik_dense(&s.w, &s.h(), &data.v)
    });
    psgld::log_info!(
        "  native : loglik {:.4e} -> {:.4e} in {:.2}s ({} samples, {} post-burn-in)",
        res.trace.values[0],
        res.trace.last_value(),
        res.sampling_seconds,
        t,
        res.posterior.count(),
    );

    if opts.has_artifacts() {
        let mut hlo =
            HloPsgld::new(&opts.artifacts, &data.v, &model, 4, run.clone(), opts.seed)?;
        let res = run_sampler(&mut hlo, &run, |s| {
            model.loglik_dense(&s.w, &s.h(), &data.v)
        });
        psgld::log_info!(
            "  hlo    : loglik {:.4e} -> {:.4e} in {:.2}s (one PJRT dispatch/iter)",
            res.trace.values[0],
            res.trace.last_value(),
            res.sampling_seconds,
        );
    } else {
        psgld::log_info!("  (HLO backend skipped: run `make artifacts`)");
    }
    Ok(())
}

/// `validate-trace PATH`: parse a trace JSON and run the schema check.
fn validate_trace_cmd(path: &str) -> psgld::Result<()> {
    let text = std::fs::read_to_string(path)?;
    let parsed = psgld::util::Json::parse(&text)?;
    psgld::obs::validate_trace(&parsed)?;
    println!("{path}: valid trace ({} bytes)", text.len());
    Ok(())
}

/// `check-regression --baseline PATH --current PATH [--tol-frac F]`:
/// compare bench/health JSON against committed baselines. Returns
/// whether the comparison passed.
fn check_regression_cmd(args: &[String]) -> Result<bool, String> {
    let mut baseline: Option<PathBuf> = None;
    let mut current: Option<PathBuf> = None;
    let mut tol_frac = 0.2f64;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--baseline" => {
                baseline = Some(PathBuf::from(
                    it.next().ok_or_else(|| "--baseline needs a value".to_string())?,
                ))
            }
            "--current" => {
                current = Some(PathBuf::from(
                    it.next().ok_or_else(|| "--current needs a value".to_string())?,
                ))
            }
            "--tol-frac" => {
                tol_frac = it
                    .next()
                    .ok_or_else(|| "--tol-frac needs a value".to_string())?
                    .parse()
                    .map_err(|e| format!("bad --tol-frac: {e}"))?
            }
            other => return Err(format!("unknown check-regression option '{other}'")),
        }
    }
    let baseline = baseline.ok_or_else(|| "check-regression needs --baseline".to_string())?;
    let current = current.ok_or_else(|| "check-regression needs --current".to_string())?;
    let report = psgld::monitor::check_regression(&baseline, &current, tol_frac)
        .map_err(|e| e.to_string())?;
    for skip in &report.skipped {
        psgld::log_warn!("check-regression: skipped {skip}");
    }
    for r in &report.regressions {
        psgld::log_error!(
            "REGRESSION {}:{} = {:.4} vs baseline {:.4} ({:.1}% of baseline, \
             tolerance {:.1}%)",
            r.file,
            r.key,
            r.current,
            r.baseline,
            100.0 * r.ratio(),
            100.0 * (1.0 - tol_frac),
        );
    }
    psgld::log_info!(
        "check-regression: {} compared, {} regressed, {} skipped (tol {:.0}%)",
        report.compared,
        report.regressions.len(),
        report.skipped.len(),
        100.0 * tol_frac,
    );
    Ok(report.passed())
}

/// Write the observability artifacts after a run: the Perfetto trace
/// (when `--trace-out` was given), the per-run summary JSON, and the
/// monitor's health/exposition files.
fn write_obs_artifacts(opts: &ExpOptions) -> psgld::Result<()> {
    if psgld::obs::level() == psgld::obs::ObsLevel::Off {
        return Ok(());
    }
    if let Some(trace_path) = &opts.trace_out {
        psgld::obs::write_chrome_trace(trace_path, &[])?;
        println!("  wrote {}", trace_path.display());
    }
    let summary = opts.outdir.join("obs_summary.json");
    psgld::obs::write_summary(&summary)?;
    println!("  wrote {}", summary.display());
    let prom = opts.outdir.join("metrics.prom");
    std::fs::write(&prom, psgld::monitor::render_openmetrics())?;
    println!("  wrote {}", prom.display());
    let health = opts.outdir.join("health.jsonl");
    let n_events = psgld::monitor::write_health_jsonl(&health)?;
    println!("  wrote {} ({n_events} health events)", health.display());
    let health_summary = opts.outdir.join("health_summary.json");
    std::fs::write(
        &health_summary,
        psgld::monitor::health_summary_json().to_string_pretty(),
    )?;
    println!("  wrote {}", health_summary.display());
    Ok(())
}

fn dispatch(cmd: &str, opts: &ExpOptions) -> psgld::Result<()> {
    std::fs::create_dir_all(&opts.outdir)?;
    // Held across the whole run so a scraper can watch it live;
    // dropped (and the port released) on the way out.
    let _metrics_server = match &opts.metrics_addr {
        Some(addr) => Some(psgld::monitor::MetricsServer::spawn(addr)?),
        None => None,
    };
    match cmd {
        "quickstart" => quickstart(opts)?,
        "fig2a" => {
            fig2::fig2a(opts)?;
        }
        "fig2b" => {
            fig2::fig2b(opts)?;
        }
        "fig3" => {
            fig3::fig3(opts)?;
        }
        "fig5" => {
            fig5::fig5(opts)?;
        }
        "fig6a" => {
            fig6::fig6a(opts)?;
        }
        "fig6b" => {
            fig6::fig6b(opts)?;
        }
        "comm" => fig6::comm_comparison(opts)?,
        "ablations" => ablations::run_all(opts)?,
        "all" => {
            quickstart(opts)?;
            fig2::fig2a(opts)?;
            fig2::fig2b(opts)?;
            fig3::fig3(opts)?;
            fig5::fig5(opts)?;
            fig6::fig6a(opts)?;
            fig6::fig6b(opts)?;
            fig6::comm_comparison(opts)?;
            ablations::run_all(opts)?;
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            std::process::exit(2);
        }
    }
    write_obs_artifacts(opts)?;
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{HELP}");
        return ExitCode::from(2);
    };
    if cmd == "help" || cmd == "--help" || cmd == "-h" {
        println!("{HELP}");
        return ExitCode::SUCCESS;
    }
    if cmd == "validate-trace" {
        let Some(path) = args.get(1) else {
            eprintln!("error: validate-trace needs a PATH argument\n\n{HELP}");
            return ExitCode::from(2);
        };
        return match validate_trace_cmd(path) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("error: {path}: {e}");
                ExitCode::FAILURE
            }
        };
    }
    if cmd == "check-regression" {
        return match check_regression_cmd(&args[1..]) {
            Ok(true) => ExitCode::SUCCESS,
            Ok(false) => ExitCode::FAILURE,
            Err(e) => {
                eprintln!("error: {e}\n\n{HELP}");
                ExitCode::from(2)
            }
        };
    }
    let mut opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            return ExitCode::from(2);
        }
    };
    if opts.metrics_addr.is_none() {
        opts.metrics_addr =
            std::env::var("PALLAS_METRICS_ADDR").ok().filter(|a| !a.is_empty());
    }
    if opts.trace_out.is_some() && std::env::var_os("PALLAS_OBS").is_none() {
        psgld::obs::set_level_override(Some(psgld::obs::ObsLevel::Full));
    } else if opts.metrics_addr.is_some() && std::env::var_os("PALLAS_OBS").is_none() {
        psgld::obs::set_level_override(Some(psgld::obs::ObsLevel::Counters));
    }
    match dispatch(cmd, &opts) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
