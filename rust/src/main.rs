//! `psgld` — CLI launcher for the PSGLD reproduction.
//!
//! One subcommand per experiment in DESIGN.md §5 (clap is unavailable
//! offline, so argument parsing is hand-rolled; `psgld help` documents
//! everything).

use std::path::PathBuf;
use std::process::ExitCode;

use psgld::experiments::{ablations, fig2, fig3, fig5, fig6, ExpOptions};

const HELP: &str = "\
psgld — Parallel Stochastic Gradient MCMC for Matrix Factorisation
(Şimşekli et al., 2015 reproduction)

USAGE:
    psgld <COMMAND> [OPTIONS]

COMMANDS:
    quickstart        tiny end-to-end PSGLD run (native + HLO backends)
    fig2a             Poisson-NMF mixing + runtimes (Gibbs/LD/SGLD/PSGLD)
    fig2b             compound-Poisson mixing + runtimes (LD/SGLD/PSGLD)
    fig3              audio spectrogram decomposition (PSGLD/LD/Gibbs)
    fig5              MovieLens RMSE: PSGLD vs DSGD (sparse, B=15, K=50)
    fig6a             strong scaling on the simulated cluster (5..120 nodes)
    fig6b             weak scaling (data x4 & nodes x2 per step)
    comm              DSGLD-vs-PSGLD communication comparison (§1 claim)
    ablations         schedule / mirroring / B / backend ablations
    all               every experiment in sequence
    help              this text

OPTIONS:
    --out DIR         output directory for CSVs        [results]
    --artifacts DIR   AOT artifact directory           [artifacts]
    --seed N          master RNG seed                  [2015]
    --iters N         override iteration count
    --full            paper-scale runs (hours, not minutes)
    --no-gibbs        skip the Gibbs comparator

EXAMPLES:
    psgld quickstart
    psgld fig2a --iters 1000
    psgld fig5 --full --out results/full
";

fn parse_opts(args: &[String]) -> Result<ExpOptions, String> {
    let mut opts = ExpOptions::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--out" => {
                opts.outdir = PathBuf::from(
                    it.next().ok_or_else(|| "--out needs a value".to_string())?,
                )
            }
            "--artifacts" => {
                opts.artifacts = PathBuf::from(
                    it.next().ok_or_else(|| "--artifacts needs a value".to_string())?,
                )
            }
            "--seed" => {
                opts.seed = it
                    .next()
                    .ok_or_else(|| "--seed needs a value".to_string())?
                    .parse()
                    .map_err(|e| format!("bad --seed: {e}"))?
            }
            "--iters" => {
                opts.iters = Some(
                    it.next()
                        .ok_or_else(|| "--iters needs a value".to_string())?
                        .parse()
                        .map_err(|e| format!("bad --iters: {e}"))?,
                )
            }
            "--full" => opts.full = true,
            "--no-gibbs" => opts.gibbs = false,
            other => return Err(format!("unknown option '{other}'")),
        }
    }
    Ok(opts)
}

fn quickstart(opts: &ExpOptions) -> psgld::Result<()> {
    use psgld::config::{RunConfig, StepSchedule};
    use psgld::coordinator::HloPsgld;
    use psgld::data::synth;
    use psgld::model::NmfModel;
    use psgld::samplers::{run_sampler, Psgld};

    println!("PSGLD quickstart: 128x128 Poisson-NMF, K=16, B=4");
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, opts.seed);
    let t = opts.t(400, 2_000);
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

    let mut native = Psgld::new(&data.v, &model, 4, run.clone(), opts.seed);
    let res = run_sampler(&mut native, &run, |s| {
        model.loglik_dense(&s.w, &s.h(), &data.v)
    });
    println!(
        "  native : loglik {:.4e} -> {:.4e} in {:.2}s ({} samples, {} post-burn-in)",
        res.trace.values[0],
        res.trace.last_value(),
        res.sampling_seconds,
        t,
        res.posterior.count(),
    );

    if opts.has_artifacts() {
        let mut hlo =
            HloPsgld::new(&opts.artifacts, &data.v, &model, 4, run.clone(), opts.seed)?;
        let res = run_sampler(&mut hlo, &run, |s| {
            model.loglik_dense(&s.w, &s.h(), &data.v)
        });
        println!(
            "  hlo    : loglik {:.4e} -> {:.4e} in {:.2}s (one PJRT dispatch/iter)",
            res.trace.values[0],
            res.trace.last_value(),
            res.sampling_seconds,
        );
    } else {
        println!("  (HLO backend skipped: run `make artifacts`)");
    }
    Ok(())
}

fn dispatch(cmd: &str, opts: &ExpOptions) -> psgld::Result<()> {
    std::fs::create_dir_all(&opts.outdir)?;
    match cmd {
        "quickstart" => quickstart(opts)?,
        "fig2a" => {
            fig2::fig2a(opts)?;
        }
        "fig2b" => {
            fig2::fig2b(opts)?;
        }
        "fig3" => {
            fig3::fig3(opts)?;
        }
        "fig5" => {
            fig5::fig5(opts)?;
        }
        "fig6a" => {
            fig6::fig6a(opts)?;
        }
        "fig6b" => {
            fig6::fig6b(opts)?;
        }
        "comm" => fig6::comm_comparison(opts)?,
        "ablations" => ablations::run_all(opts)?,
        "all" => {
            quickstart(opts)?;
            fig2::fig2a(opts)?;
            fig2::fig2b(opts)?;
            fig3::fig3(opts)?;
            fig5::fig5(opts)?;
            fig6::fig6a(opts)?;
            fig6::fig6b(opts)?;
            fig6::comm_comparison(opts)?;
            ablations::run_all(opts)?;
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{HELP}");
        return ExitCode::from(2);
    };
    if cmd == "help" || cmd == "--help" || cmd == "-h" {
        println!("{HELP}");
        return ExitCode::SUCCESS;
    }
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            return ExitCode::from(2);
        }
    };
    match dispatch(cmd, &opts) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
