//! Zero-overhead observability: sharded metrics, hot-path spans,
//! leveled logging and Perfetto-compatible trace export.
//!
//! The paper's claim is that PSGLD's per-iteration cost stays near
//! SGD's while scaling across cores and nodes — this layer makes the
//! *where does an iteration's time go* question answerable (kernel vs.
//! noise vs. scheduling vs. ring comms vs. staleness stalls) without
//! perturbing the thing being measured. Three levels, selected by the
//! `PALLAS_OBS` environment variable:
//!
//! * `off` (default) — every instrumentation point is a single relaxed
//!   atomic load and a branch. No clock reads, no allocation: the
//!   counting-allocator test and the bitwise-determinism tests run with
//!   the instrumented binary and must keep passing.
//! * `counters` — spans record durations into per-thread **shards**
//!   (fixed-size counter/histogram arrays behind relaxed atomics, one
//!   shard per thread, merged only at collection time), so the hot path
//!   never takes a lock and never allocates once a thread's shard
//!   exists.
//! * `full` — additionally buffers one trace event per span into a
//!   per-thread buffer for Chrome/Perfetto timeline export
//!   ([`write_chrome_trace`]); the async cluster simulator also emits
//!   virtual-time slices (compute / stall / comms / rollback) on one
//!   track per node.
//!
//! Observability never touches an RNG stream and never feeds back into
//! control flow, so the chain is bitwise identical at every level.
//!
//! The leveled logger ([`logger`], `PALLAS_LOG`, default `info` =
//! pre-existing behaviour) replaces the ad-hoc `println!` call sites in
//! library code.

pub mod export;
pub mod logger;
pub mod metrics;
pub mod span;

pub use export::{validate_trace, write_chrome_trace, write_summary, VtEvent};
pub use logger::{log_enabled, log_event, set_log_override, LogLevel};
pub use metrics::{counter_add, reset, snapshot, Counter, MetricsSnapshot};
pub use span::{clear_events, drain_events, Span, TraceEvent};

use std::sync::atomic::{AtomicU8, Ordering};

/// Instrumentation level (see the module docs). Levels are ordered:
/// `Off < Counters < Full`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsLevel {
    /// No clocks, no recording; a single relaxed load per site.
    Off,
    /// Durations into sharded counters/histograms; no trace events.
    Counters,
    /// Counters plus buffered trace events for timeline export.
    Full,
}

impl ObsLevel {
    /// Parse a `PALLAS_OBS` value. Unknown strings parse to `None`
    /// (callers fall back to `Off`).
    pub fn parse(s: &str) -> Option<ObsLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "" => Some(ObsLevel::Off),
            "counters" | "1" => Some(ObsLevel::Counters),
            "full" | "trace" | "2" => Some(ObsLevel::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Counters => "counters",
            ObsLevel::Full => "full",
        }
    }

    fn from_u8(v: u8) -> Option<ObsLevel> {
        match v {
            0 => Some(ObsLevel::Off),
            1 => Some(ObsLevel::Counters),
            2 => Some(ObsLevel::Full),
            _ => None,
        }
    }
}

const LEVEL_UNSET: u8 = u8::MAX;
/// Cached `PALLAS_OBS` detection (env read once).
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);
/// Test/CLI hook; takes precedence over the environment.
static LEVEL_OVERRIDE: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn detect() -> ObsLevel {
    std::env::var("PALLAS_OBS")
        .ok()
        .and_then(|v| ObsLevel::parse(&v))
        .unwrap_or(ObsLevel::Off)
}

/// The active instrumentation level. This is the one load every
/// instrumentation point performs; with `Off` nothing else runs.
#[inline]
pub fn level() -> ObsLevel {
    if let Some(l) = ObsLevel::from_u8(LEVEL_OVERRIDE.load(Ordering::Relaxed)) {
        return l;
    }
    match ObsLevel::from_u8(LEVEL.load(Ordering::Relaxed)) {
        Some(l) => l,
        None => {
            let l = detect();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
    }
}

/// Force a level (tests, benches and the CLI `--trace-out` path);
/// `None` restores `PALLAS_OBS` detection. Flipping the level never
/// changes numerical results — only what gets recorded.
pub fn set_level_override(l: Option<ObsLevel>) {
    LEVEL_OVERRIDE.store(l.map(|l| l as u8).unwrap_or(LEVEL_UNSET), Ordering::Relaxed);
}

/// Number of span phases (the fixed taxonomy below).
pub const PHASE_COUNT: usize = 11;

/// The span taxonomy. Fixed at compile time so the per-thread shards
/// are plain arrays — registering a phase can never allocate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// One whole sampler iteration (`Psgld::step`).
    Step,
    /// Part scheduling + step-size/nonneg-hint preparation.
    Schedule,
    /// Gradient accumulation (sparse CSR walk or tiled dense kernel).
    Kernel,
    /// Langevin/SGD parameter application incl. noise generation.
    Noise,
    /// Ring messages on the wire (virtual time in the async executor).
    Comms,
    /// Blocked on the bounded-staleness rule.
    Stall,
    /// Consistent checkpoint writes.
    Checkpoint,
    /// Crash recovery (coordinated rollback + restart delay).
    Rollback,
    /// Monitor/diagnostic evaluation (excluded from sampling time).
    Monitor,
    /// One worker slot's share of a pool epoch.
    PoolTask,
    /// Artifact/manifest I/O.
    Io,
}

impl Phase {
    pub const ALL: [Phase; PHASE_COUNT] = [
        Phase::Step,
        Phase::Schedule,
        Phase::Kernel,
        Phase::Noise,
        Phase::Comms,
        Phase::Stall,
        Phase::Checkpoint,
        Phase::Rollback,
        Phase::Monitor,
        Phase::PoolTask,
        Phase::Io,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Schedule => "schedule",
            Phase::Kernel => "kernel",
            Phase::Noise => "noise",
            Phase::Comms => "comms",
            Phase::Stall => "stall",
            Phase::Checkpoint => "checkpoint",
            Phase::Rollback => "rollback",
            Phase::Monitor => "monitor",
            Phase::PoolTask => "pool_task",
            Phase::Io => "io",
        }
    }

    /// Shard array index.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

/// Serialize unit tests that flip the global level override (the lib
/// test binary runs tests on multiple threads).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    GUARD
        .get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_contract() {
        assert_eq!(ObsLevel::parse("off"), Some(ObsLevel::Off));
        assert_eq!(ObsLevel::parse("OFF"), Some(ObsLevel::Off));
        assert_eq!(ObsLevel::parse("counters"), Some(ObsLevel::Counters));
        assert_eq!(ObsLevel::parse("full"), Some(ObsLevel::Full));
        assert_eq!(ObsLevel::parse(" full "), Some(ObsLevel::Full));
        assert_eq!(ObsLevel::parse("banana"), None);
        assert!(ObsLevel::Off < ObsLevel::Counters);
        assert!(ObsLevel::Counters < ObsLevel::Full);
    }

    #[test]
    fn override_wins_and_restores() {
        let _g = test_guard();
        set_level_override(Some(ObsLevel::Counters));
        assert_eq!(level(), ObsLevel::Counters);
        set_level_override(Some(ObsLevel::Full));
        assert_eq!(level(), ObsLevel::Full);
        set_level_override(None);
        // back to env detection (no PALLAS_OBS in the test env → Off,
        // but any cached value is acceptable — just must not panic)
        let _ = level();
    }

    #[test]
    fn phase_taxonomy_is_dense() {
        assert_eq!(Phase::ALL.len(), PHASE_COUNT);
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
            assert!(!p.name().is_empty());
        }
    }
}
