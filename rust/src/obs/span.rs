//! RAII phase spans and per-thread trace-event buffers.
//!
//! [`Span::enter`] is the single instrumentation primitive on the wall
//! clock: with obs off it is a relaxed load and a branch (no clock
//! read); at `counters` it records its duration into the sharded
//! metrics on drop; at `full` it additionally appends one
//! [`TraceEvent`] to its thread's buffer for timeline export. Buffers
//! are drained by [`drain_events`] (export time only). Virtual-time
//! slices from the async cluster simulator use
//! [`super::export::VtEvent`] instead — virtual time has no wall
//! clock.

use std::cell::OnceCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::metrics::{self, Counter};
use super::{level, ObsLevel, Phase};

/// Per-thread cap on buffered trace events (~44 MB at 44 B/event).
/// Overflow drops the event and bumps [`Counter::TraceEventsDropped`]
/// rather than growing without bound.
const EVENT_CAP: usize = 1 << 20;

/// One completed wall-clock span, timestamped in nanoseconds since the
/// process's first instrumented event.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Static site label (e.g. `"grads_sparse"`).
    pub name: &'static str,
    /// Taxonomy phase (becomes the Chrome trace `cat`).
    pub phase: Phase,
    /// Stable per-thread track id (dense, assigned on first event).
    pub tid: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch (first instrumented event).
pub(super) fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

struct ThreadBuf {
    tid: u32,
    events: Arc<Mutex<Vec<TraceEvent>>>,
}

static NEXT_TID: AtomicU32 = AtomicU32::new(0);

fn buf_registry() -> &'static Mutex<Vec<Arc<Mutex<Vec<TraceEvent>>>>> {
    static REG: OnceLock<Mutex<Vec<Arc<Mutex<Vec<TraceEvent>>>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

fn thread_names() -> &'static Mutex<BTreeMap<u32, String>> {
    static NAMES: OnceLock<Mutex<BTreeMap<u32, String>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(BTreeMap::new()))
}

thread_local! {
    static TBUF: OnceCell<ThreadBuf> = OnceCell::new();
}

fn with_buf<R>(f: impl FnOnce(&ThreadBuf) -> R) -> R {
    TBUF.with(|cell| {
        let buf = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(|n| n.to_string())
                .unwrap_or_else(|| format!("thread-{tid}"));
            thread_names().lock().unwrap_or_else(|e| e.into_inner()).insert(tid, name);
            let events = Arc::new(Mutex::new(Vec::new()));
            buf_registry().lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&events));
            ThreadBuf { tid, events }
        });
        f(buf)
    })
}

/// RAII span guard. Construct with [`Span::enter`]; the interval ends
/// when the guard drops. Bind it to a named `_span` variable — `let _ =`
/// would drop immediately.
pub struct Span {
    phase: Phase,
    name: &'static str,
    start_ns: u64,
    armed: bool,
}

impl Span {
    /// Open a span for `phase` at the current wall time. With obs off
    /// this reads no clock and records nothing.
    #[inline]
    pub fn enter(phase: Phase, name: &'static str) -> Span {
        if level() == ObsLevel::Off {
            return Span { phase, name, start_ns: 0, armed: false };
        }
        Span { phase, name, start_ns: now_ns(), armed: true }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur = now_ns().saturating_sub(self.start_ns);
        metrics::record_duration(self.phase, dur);
        if level() == ObsLevel::Full {
            let dropped = with_buf(|buf| {
                let mut ev = buf.events.lock().unwrap_or_else(|e| e.into_inner());
                if ev.len() < EVENT_CAP {
                    ev.push(TraceEvent {
                        name: self.name,
                        phase: self.phase,
                        tid: buf.tid,
                        start_ns: self.start_ns,
                        dur_ns: dur,
                    });
                    false
                } else {
                    true
                }
            });
            if dropped {
                metrics::counter_add(Counter::TraceEventsDropped, 1);
            }
        }
    }
}

/// Drain every thread's buffered events (sorted by start time) along
/// with the `tid → thread name` table for track naming. Export-time
/// only.
pub fn drain_events() -> (Vec<TraceEvent>, Vec<(u32, String)>) {
    let mut out = Vec::new();
    {
        let bufs = buf_registry().lock().unwrap_or_else(|e| e.into_inner());
        for b in bufs.iter() {
            let mut ev = b.lock().unwrap_or_else(|e| e.into_inner());
            out.append(&mut ev);
        }
    }
    out.sort_by_key(|e| (e.start_ns, e.tid));
    let names = thread_names()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|(k, v)| (*k, v.clone()))
        .collect();
    (out, names)
}

/// Discard all buffered events (tests and multi-run benches).
pub fn clear_events() {
    let bufs = buf_registry().lock().unwrap_or_else(|e| e.into_inner());
    for b in bufs.iter() {
        b.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_event_at_full() {
        let _g = super::super::test_guard();
        super::super::set_level_override(Some(ObsLevel::Full));
        clear_events();
        {
            let _span = Span::enter(Phase::Io, "span_test_site");
            std::hint::black_box(0u64);
        }
        let (events, names) = drain_events();
        let mine: Vec<_> = events.iter().filter(|e| e.name == "span_test_site").collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].phase, Phase::Io);
        assert!(names.iter().any(|(tid, _)| *tid == mine[0].tid));
        // drained means gone
        let (again, _) = drain_events();
        assert!(!again.iter().any(|e| e.name == "span_test_site"));
        super::super::set_level_override(None);
    }

    #[test]
    fn span_is_inert_when_off() {
        let _g = super::super::test_guard();
        super::super::set_level_override(Some(ObsLevel::Off));
        clear_events();
        {
            let _span = Span::enter(Phase::Io, "span_off_site");
        }
        let (events, _) = drain_events();
        assert!(!events.iter().any(|e| e.name == "span_off_site"));
        super::super::set_level_override(None);
    }
}
