//! Minimal leveled logger controlled by `PALLAS_LOG`.
//!
//! Library code logs through the `log_error!` / `log_warn!` /
//! `log_info!` / `log_debug!` macros instead of printing
//! unconditionally. The default level is `info`, which reproduces the
//! pre-logger behaviour exactly: info lines go to stdout (tables, CSV
//! paths), warnings and errors to stderr, debug is silent. Set
//! `PALLAS_LOG=off` to silence library output entirely (suppressed
//! lines are counted in the metrics registry), or `PALLAS_LOG=debug`
//! for extra detail.

use std::sync::atomic::{AtomicU8, Ordering};

use super::metrics::{counter_add, Counter};

/// Log verbosity, ordered: a message is emitted when its level is at
/// or below the active one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Off,
    Error,
    Warn,
    Info,
    Debug,
}

impl LogLevel {
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "silent" => Some(LogLevel::Off),
            "error" => Some(LogLevel::Error),
            "warn" | "warning" => Some(LogLevel::Warn),
            "info" | "" => Some(LogLevel::Info),
            "debug" | "trace" => Some(LogLevel::Debug),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LogLevel::Off => "off",
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Option<LogLevel> {
        match v {
            0 => Some(LogLevel::Off),
            1 => Some(LogLevel::Error),
            2 => Some(LogLevel::Warn),
            3 => Some(LogLevel::Info),
            4 => Some(LogLevel::Debug),
            _ => None,
        }
    }
}

const LEVEL_UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);
static LEVEL_OVERRIDE: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn detect() -> LogLevel {
    std::env::var("PALLAS_LOG")
        .ok()
        .and_then(|v| LogLevel::parse(&v))
        .unwrap_or(LogLevel::Info)
}

/// The active log level (`PALLAS_LOG`, default `info`).
pub fn log_level() -> LogLevel {
    if let Some(l) = LogLevel::from_u8(LEVEL_OVERRIDE.load(Ordering::Relaxed)) {
        return l;
    }
    match LogLevel::from_u8(LEVEL.load(Ordering::Relaxed)) {
        Some(l) => l,
        None => {
            let l = detect();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
    }
}

/// Force a log level (tests); `None` restores `PALLAS_LOG` detection.
pub fn set_log_override(l: Option<LogLevel>) {
    LEVEL_OVERRIDE.store(l.map(|l| l as u8).unwrap_or(LEVEL_UNSET), Ordering::Relaxed);
}

/// Would a message at `lvl` be emitted?
#[inline]
pub fn log_enabled(lvl: LogLevel) -> bool {
    lvl as u8 <= log_level() as u8 && lvl != LogLevel::Off
}

/// Emit one log line (macro backend — use the `log_*!` macros).
/// Warnings and errors go to stderr, info/debug to stdout, matching
/// the pre-logger call sites.
pub fn log(lvl: LogLevel, args: std::fmt::Arguments<'_>) {
    if !log_enabled(lvl) {
        counter_add(Counter::LogLinesSuppressed, 1);
        return;
    }
    match lvl {
        LogLevel::Error | LogLevel::Warn => eprintln!("{args}"),
        _ => println!("{args}"),
    }
}

/// Emit a structured machine-readable record (e.g. a monitor health
/// event) as one compact JSON line through the leveled logger, so it
/// obeys `PALLAS_LOG` and the suppression counter like any other line.
pub fn log_event(lvl: LogLevel, event: &crate::util::Json) {
    log(lvl, format_args!("{}", event.to_string_compact()));
}

/// Log at error level (stderr). Accepts `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::LogLevel::Error, format_args!($($arg)*))
    };
}

/// Log at warn level (stderr). Accepts `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::LogLevel::Warn, format_args!($($arg)*))
    };
}

/// Log at info level (stdout, on by default). Accepts `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::LogLevel::Info, format_args!($($arg)*))
    };
}

/// Log at debug level (stdout, off by default). Accepts `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::LogLevel::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_order() {
        assert_eq!(LogLevel::parse("off"), Some(LogLevel::Off));
        assert_eq!(LogLevel::parse("WARN"), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse("debug"), Some(LogLevel::Debug));
        assert_eq!(LogLevel::parse("nope"), None);
        assert!(LogLevel::Error < LogLevel::Info);
    }

    #[test]
    fn enabled_respects_override() {
        let _g = super::super::test_guard();
        set_log_override(Some(LogLevel::Warn));
        assert!(log_enabled(LogLevel::Error));
        assert!(log_enabled(LogLevel::Warn));
        assert!(!log_enabled(LogLevel::Info));
        assert!(!log_enabled(LogLevel::Debug));
        set_log_override(Some(LogLevel::Off));
        assert!(!log_enabled(LogLevel::Error));
        set_log_override(None);
    }
}
