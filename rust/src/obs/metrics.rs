//! Per-thread sharded metrics: counters and log2 duration histograms.
//!
//! Each thread owns one [`Shard`] — fixed-size arrays of relaxed
//! `AtomicU64`s, created on that thread's first recording and
//! registered once in a global list. The hot path after that first
//! touch is a thread-local lookup plus relaxed `fetch_add`s: no lock,
//! no allocation, no contention (only [`snapshot`]/[`reset`] walk the
//! registry, and they run off the hot path).

use std::cell::OnceCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{level, ObsLevel, Phase, PHASE_COUNT};

/// Log2 histogram width. Bucket `0` holds `[0, 1]` ns; bucket `i > 0`
/// holds `(2^(i-1), 2^i]` ns; the last bucket absorbs everything
/// larger (2^38 ns ≈ 4.6 min — far beyond any span we record).
pub const HIST_BUCKETS: usize = 40;

/// Number of event counters (the fixed set below).
pub const COUNTER_COUNT: usize = 11;

/// Monotone event counters. Fixed at compile time so shard storage is
/// a plain array and incrementing can never allocate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Sampler iterations started.
    Steps,
    /// Disjoint blocks executed (all execution paths).
    Blocks,
    /// Worker-pool epochs dispatched.
    PoolEpochs,
    /// Bounded-staleness stalls entered (async executor).
    Stalls,
    /// Message retries after a simulated drop.
    Retries,
    /// Coordinated rollbacks after a crash.
    Rollbacks,
    /// Consistent checkpoints taken.
    Checkpoints,
    /// Ring messages sent.
    MsgsSent,
    /// Ring messages dropped by fault injection.
    MsgsDropped,
    /// Trace events discarded because a thread buffer hit its cap.
    TraceEventsDropped,
    /// Log lines suppressed below the active `PALLAS_LOG` level.
    LogLinesSuppressed,
}

impl Counter {
    pub const ALL: [Counter; COUNTER_COUNT] = [
        Counter::Steps,
        Counter::Blocks,
        Counter::PoolEpochs,
        Counter::Stalls,
        Counter::Retries,
        Counter::Rollbacks,
        Counter::Checkpoints,
        Counter::MsgsSent,
        Counter::MsgsDropped,
        Counter::TraceEventsDropped,
        Counter::LogLinesSuppressed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Steps => "steps",
            Counter::Blocks => "blocks",
            Counter::PoolEpochs => "pool_epochs",
            Counter::Stalls => "stalls",
            Counter::Retries => "retries",
            Counter::Rollbacks => "rollbacks",
            Counter::Checkpoints => "checkpoints",
            Counter::MsgsSent => "msgs_sent",
            Counter::MsgsDropped => "msgs_dropped",
            Counter::TraceEventsDropped => "trace_events_dropped",
            Counter::LogLinesSuppressed => "log_lines_suppressed",
        }
    }
}

/// One thread's slice of the registry. All loads/stores are relaxed:
/// the merge in [`snapshot`] tolerates tearing between fields (it is a
/// monitoring read, not a synchronisation point).
struct Shard {
    counters: [AtomicU64; COUNTER_COUNT],
    phase_count: [AtomicU64; PHASE_COUNT],
    phase_ns: [AtomicU64; PHASE_COUNT],
    hist: [[AtomicU64; HIST_BUCKETS]; PHASE_COUNT],
}

impl Shard {
    fn new() -> Shard {
        Shard {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_count: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            hist: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
        }
    }

    fn zero(&self) {
        for c in &self.counters {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.phase_count {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.phase_ns {
            c.store(0, Ordering::Relaxed);
        }
        for row in &self.hist {
            for c in row {
                c.store(0, Ordering::Relaxed);
            }
        }
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Shard>>> {
    static REG: OnceLock<Mutex<Vec<Arc<Shard>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: OnceCell<Arc<Shard>> = OnceCell::new();
}

/// Run `f` against this thread's shard, creating + registering it on
/// first use (the only allocation this module ever performs on a
/// recording thread, and it happens once per thread — warmup in the
/// counting-allocator test absorbs it).
fn with_shard<R>(f: impl FnOnce(&Shard) -> R) -> R {
    LOCAL.with(|cell| {
        let shard = cell.get_or_init(|| {
            let s = Arc::new(Shard::new());
            registry().lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&s));
            s
        });
        f(shard)
    })
}

/// Bump a counter by `n`. A relaxed load + early return when obs is
/// off.
#[inline]
pub fn counter_add(c: Counter, n: u64) {
    if level() == ObsLevel::Off {
        return;
    }
    with_shard(|s| {
        s.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    });
}

/// Histogram bucket for a duration in nanoseconds (see [`HIST_BUCKETS`]).
#[inline]
fn bucket(ns: u64) -> usize {
    if ns <= 1 {
        0
    } else {
        (64 - (ns - 1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Record one completed span duration. Called from the span guard's
/// drop; callers have already checked the level is at least
/// `Counters`.
pub(super) fn record_duration(phase: Phase, ns: u64) {
    with_shard(|s| {
        let p = phase.idx();
        s.phase_count[p].fetch_add(1, Ordering::Relaxed);
        s.phase_ns[p].fetch_add(ns, Ordering::Relaxed);
        s.hist[p][bucket(ns)].fetch_add(1, Ordering::Relaxed);
    });
}

/// A merged, immutable view of every shard at one point in time.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Indexed by `Counter as usize`.
    pub counters: Vec<u64>,
    /// Spans completed per phase, indexed by `Phase::idx()`.
    pub phase_count: Vec<u64>,
    /// Total nanoseconds per phase, indexed by `Phase::idx()`.
    pub phase_ns: Vec<u64>,
    /// Log2 duration histogram per phase: `hist[phase][bucket]`.
    pub hist: Vec<Vec<u64>>,
}

impl MetricsSnapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn phase_seconds(&self, p: Phase) -> f64 {
        self.phase_ns[p.idx()] as f64 * 1e-9
    }

    /// Quantile estimate (in ns) from the log2 histogram: the upper
    /// edge of the bucket containing the `q`-th sample, i.e. an upper
    /// bound tight to within 2x. Returns 0.0 for an empty histogram.
    pub fn quantile_ns(&self, p: Phase, q: f64) -> f64 {
        let h = &self.hist[p.idx()];
        let total: u64 = h.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in h.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << i) as f64;
            }
        }
        (1u64 << (HIST_BUCKETS - 1)) as f64
    }
}

/// Merge every registered shard into one snapshot.
pub fn snapshot() -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        counters: vec![0; COUNTER_COUNT],
        phase_count: vec![0; PHASE_COUNT],
        phase_ns: vec![0; PHASE_COUNT],
        hist: vec![vec![0; HIST_BUCKETS]; PHASE_COUNT],
    };
    let shards = registry().lock().unwrap_or_else(|e| e.into_inner());
    for s in shards.iter() {
        for (o, c) in out.counters.iter_mut().zip(&s.counters) {
            *o += c.load(Ordering::Relaxed);
        }
        for (o, c) in out.phase_count.iter_mut().zip(&s.phase_count) {
            *o += c.load(Ordering::Relaxed);
        }
        for (o, c) in out.phase_ns.iter_mut().zip(&s.phase_ns) {
            *o += c.load(Ordering::Relaxed);
        }
        for (orow, srow) in out.hist.iter_mut().zip(&s.hist) {
            for (o, c) in orow.iter_mut().zip(srow) {
                *o += c.load(Ordering::Relaxed);
            }
        }
    }
    out
}

/// Zero every registered shard (tests and multi-run benches). Threads
/// keep their shards; only the counts reset.
pub fn reset() {
    let shards = registry().lock().unwrap_or_else(|e| e.into_inner());
    for s in shards.iter() {
        s.zero();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(5), 3);
        assert_eq!(bucket(1024), 10);
        assert_eq!(bucket(1025), 11);
        assert_eq!(bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn record_and_snapshot_roundtrip() {
        let _g = super::super::test_guard();
        super::super::set_level_override(Some(ObsLevel::Counters));
        let before = snapshot();
        counter_add(Counter::Blocks, 3);
        record_duration(Phase::Kernel, 1000);
        record_duration(Phase::Kernel, 2000);
        // deltas are >= (never ==): concurrent tests outside this
        // module may record while the override is non-Off
        let s = snapshot();
        assert!(s.counter(Counter::Blocks) >= before.counter(Counter::Blocks) + 3);
        let k = Phase::Kernel.idx();
        assert!(s.phase_count[k] >= before.phase_count[k] + 2);
        assert!(s.phase_ns[k] >= before.phase_ns[k] + 3000);
        assert!(s.phase_seconds(Phase::Kernel) >= 3e-6 - 1e-12);
        // the max bucket edge must cover the 2000ns sample
        assert!(s.quantile_ns(Phase::Kernel, 1.0) >= 2000.0);
        // once the level is Off nothing can record, so reset() leaves
        // an exactly-zero registry
        super::super::set_level_override(Some(ObsLevel::Off));
        reset();
        let z = snapshot();
        assert_eq!(z.counter(Counter::Blocks), 0);
        assert_eq!(z.phase_count[k], 0);
        assert_eq!(z.quantile_ns(Phase::Kernel, 0.5), 0.0);
        super::super::set_level_override(None);
    }

    #[test]
    fn counter_add_is_inert_when_off() {
        let _g = super::super::test_guard();
        super::super::set_level_override(Some(ObsLevel::Off));
        let before = snapshot().counter(Counter::Retries);
        counter_add(Counter::Retries, 5);
        assert_eq!(snapshot().counter(Counter::Retries), before);
        super::super::set_level_override(None);
    }
}
