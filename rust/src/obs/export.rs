//! Trace and summary exporters.
//!
//! [`write_chrome_trace`] emits the Chrome trace-event JSON format
//! (the `traceEvents` array of `"ph":"X"` complete slices), which
//! Perfetto (<https://ui.perfetto.dev>) and `chrome://tracing` load
//! directly. Two synthetic processes separate the clock domains:
//!
//! * `pid 0` — wall clock; one track (`tid`) per OS thread that
//!   recorded spans, named after the thread.
//! * `pid 1` — the async cluster simulator's **virtual time**; one
//!   track per simulated node, with compute / stall / comms /
//!   rollback / checkpoint slices ([`VtEvent`]).
//!
//! Timestamps are microseconds as the format requires. Virtual-time
//! slices reuse the same unit, so "1 ms" on a cluster track means one
//! simulated millisecond.
//!
//! [`write_summary`] emits a small per-run JSON next to the CSVs:
//! per-phase totals and histogram quantiles plus the event counters.
//! [`validate_trace`] is the schema check used by tests and the CLI
//! `validate-trace` subcommand.

use std::path::Path;

use crate::util::Json;
use crate::{Error, Result};

use super::metrics::{snapshot, Counter, MetricsSnapshot};
use super::{level, span, Phase};

/// One slice on a virtual-time track (async cluster simulator). Times
/// are simulated seconds; `track` is the node index.
#[derive(Clone, Copy, Debug)]
pub struct VtEvent {
    /// Slice label (`"compute"`, `"stall"`, `"msg"`, ...).
    pub name: &'static str,
    /// Taxonomy phase name used as the trace `cat` (must be one of
    /// [`Phase::name`]'s values — [`validate_trace`] enforces this).
    pub cat: &'static str,
    /// Simulated node index (one Perfetto track per node).
    pub track: u32,
    pub start_s: f64,
    pub dur_s: f64,
}

fn slice(name: &str, cat: &str, pid: u32, tid: u32, ts_us: f64, dur_us: f64) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("X")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(tid)),
        ("ts", Json::num(ts_us)),
        ("dur", Json::num(dur_us)),
    ])
}

fn metadata(kind: &str, pid: u32, tid: Option<u32>, label: &str) -> Json {
    let mut pairs = vec![
        ("name", Json::str(kind)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
        ("args", Json::obj(vec![("name", Json::str(label))])),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", Json::num(t)));
    }
    Json::obj(pairs)
}

/// Drain all buffered wall-clock spans, merge in the given
/// virtual-time slices, and write a Perfetto-loadable trace JSON.
pub fn write_chrome_trace(path: &Path, vt_events: &[VtEvent]) -> Result<()> {
    let (events, names) = span::drain_events();
    let mut list: Vec<Json> = Vec::new();

    list.push(metadata("process_name", 0, None, "wall-clock"));
    for (tid, name) in &names {
        list.push(metadata("thread_name", 0, Some(*tid), name));
    }
    if !vt_events.is_empty() {
        list.push(metadata("process_name", 1, None, "cluster-virtual-time"));
        let mut tracks: Vec<u32> = vt_events.iter().map(|e| e.track).collect();
        tracks.sort_unstable();
        tracks.dedup();
        for t in tracks {
            list.push(metadata("thread_name", 1, Some(t), &format!("node-{t}")));
        }
    }

    for e in &events {
        list.push(slice(
            e.name,
            e.phase.name(),
            0,
            e.tid,
            e.start_ns as f64 / 1e3,
            e.dur_ns as f64 / 1e3,
        ));
    }
    for v in vt_events {
        list.push(slice(v.name, v.cat, 1, v.track, v.start_s * 1e6, v.dur_s * 1e6));
    }

    let root = Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(list)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, root.to_string_compact())?;
    Ok(())
}

/// Schema check for a trace produced by [`write_chrome_trace`]: a
/// `traceEvents` array whose entries are either `"M"` metadata records
/// or `"X"` complete slices with non-negative `ts`/`dur` and a `cat`
/// from the span taxonomy. At least one slice must be present.
pub fn validate_trace(trace: &Json) -> Result<()> {
    let events = trace.field("traceEvents")?.as_arr()?;
    let mut slices = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ctx = |msg: String| Error::Config(format!("traceEvents[{i}]: {msg}"));
        let ph = e.field("ph")?.as_str()?;
        e.field("name")?.as_str()?;
        e.field("pid")?.as_usize()?;
        match ph {
            "M" => {
                e.field("args")?.field("name")?.as_str()?;
            }
            "X" => {
                slices += 1;
                e.field("tid")?.as_usize()?;
                let ts = e.field("ts")?.as_f64()?;
                let dur = e.field("dur")?.as_f64()?;
                if !(ts >= 0.0 && ts.is_finite()) {
                    return Err(ctx(format!("bad ts {ts}")));
                }
                if !(dur >= 0.0 && dur.is_finite()) {
                    return Err(ctx(format!("bad dur {dur}")));
                }
                let cat = e.field("cat")?.as_str()?;
                if !Phase::ALL.iter().any(|p| p.name() == cat) {
                    return Err(ctx(format!("unknown category '{cat}'")));
                }
            }
            other => return Err(ctx(format!("unknown ph '{other}'"))),
        }
    }
    if slices == 0 {
        return Err(Error::Config("trace contains no duration slices".into()));
    }
    Ok(())
}

fn phase_entry(s: &MetricsSnapshot, p: Phase) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.phase_count[p.idx()] as f64)),
        ("total_s", Json::num(s.phase_seconds(p))),
        ("p50_ns", Json::num(s.quantile_ns(p, 0.5))),
        ("p90_ns", Json::num(s.quantile_ns(p, 0.9))),
        ("p99_ns", Json::num(s.quantile_ns(p, 0.99))),
    ])
}

/// Build the per-run summary (phase totals + quantiles + counters)
/// from the current metrics snapshot.
pub fn summary_json() -> Json {
    let s = snapshot();
    let phases = Phase::ALL.iter().map(|p| (p.name(), phase_entry(&s, *p))).collect();
    let counters =
        Counter::ALL.iter().map(|c| (c.name(), Json::num(s.counter(*c) as f64))).collect();
    Json::obj(vec![
        ("schema", Json::str("psgld-obs-summary/1")),
        ("level", Json::str(level().name())),
        ("phases", Json::obj(phases)),
        ("counters", Json::obj(counters)),
    ])
}

/// Write the per-run summary JSON (see [`summary_json`]).
pub fn write_summary(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, summary_json().to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{set_level_override, ObsLevel, Span};

    #[test]
    fn trace_roundtrips_and_validates() {
        let _g = crate::obs::test_guard();
        set_level_override(Some(ObsLevel::Full));
        span::clear_events();
        {
            let _s = Span::enter(Phase::Io, "export_test_span");
        }
        let vt = [
            VtEvent { name: "compute", cat: "kernel", track: 0, start_s: 0.0, dur_s: 0.5 },
            VtEvent { name: "stall", cat: "stall", track: 1, start_s: 0.25, dur_s: 0.1 },
        ];
        let dir = std::env::temp_dir().join("psgld_obs_export_test");
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &vt).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        validate_trace(&parsed).unwrap();
        // the vt slices land on pid 1 with µs timestamps
        let events = parsed.field("traceEvents").unwrap().as_arr().unwrap();
        let stall = events
            .iter()
            .find(|e| {
                e.field_opt("name").and_then(|n| n.as_str().ok()) == Some("stall")
                    && e.field_opt("ph").and_then(|p| p.as_str().ok()) == Some("X")
            })
            .expect("stall slice present");
        assert_eq!(stall.field("pid").unwrap().as_usize().unwrap(), 1);
        assert!((stall.field("ts").unwrap().as_f64().unwrap() - 0.25e6).abs() < 1e-6);
        let _ = std::fs::remove_dir_all(&dir);
        set_level_override(None);
    }

    #[test]
    fn validator_rejects_malformed() {
        assert!(validate_trace(&Json::parse(r#"{"traceEvents":[]}"#).unwrap()).is_err());
        assert!(validate_trace(&Json::parse("{}").unwrap()).is_err());
        let neg = r#"{"traceEvents":[{"name":"x","cat":"kernel","ph":"X",
            "pid":0,"tid":0,"ts":-1,"dur":1}]}"#;
        assert!(validate_trace(&Json::parse(neg).unwrap()).is_err());
        let badcat = r#"{"traceEvents":[{"name":"x","cat":"nonsense","ph":"X",
            "pid":0,"tid":0,"ts":0,"dur":1}]}"#;
        assert!(validate_trace(&Json::parse(badcat).unwrap()).is_err());
        let badph = r#"{"traceEvents":[{"name":"x","ph":"B","pid":0}]}"#;
        assert!(validate_trace(&Json::parse(badph).unwrap()).is_err());
    }

    #[test]
    fn summary_schema() {
        let s = summary_json();
        assert_eq!(s.field("schema").unwrap().as_str().unwrap(), "psgld-obs-summary/1");
        let phases = s.field("phases").unwrap();
        for p in Phase::ALL {
            let e = phases.field(p.name()).unwrap();
            assert!(e.field("total_s").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.field("p99_ns").unwrap().as_f64().unwrap() >= 0.0);
        }
        let counters = s.field("counters").unwrap();
        for c in Counter::ALL {
            counters.field(c.name()).unwrap();
        }
    }
}
