//! Configuration system: experiment configs with JSON round-tripping
//! (via the in-crate [`crate::util::json`] substrate), step-size
//! schedules and run settings shared by every sampler and the CLI.

use std::path::Path;

use crate::model::NmfModel;
use crate::partition::PartSchedule;
use crate::util::Json;
use crate::{Error, Result};

/// Re-export so configs and models travel together.
pub type ModelConfig = NmfModel;

/// Step-size schedule ε_t (paper Eq. 4 conditions).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepSchedule {
    /// Constant ε (the paper's LD baseline uses ε = 0.2).
    Constant { eps: f64 },
    /// ε_t = (a / t)^b with b ∈ (0.5, 1] (the paper's SGLD/PSGLD choice).
    Polynomial { a: f64, b: f64 },
}

impl StepSchedule {
    /// ε at iteration `t` (1-based).
    #[inline]
    pub fn eps(&self, t: u64) -> f64 {
        match *self {
            StepSchedule::Constant { eps } => eps,
            StepSchedule::Polynomial { a, b } => (a / t.max(1) as f64).powf(b),
        }
    }

    /// Check the Robbins-Monro conditions (Σε = ∞, Σε² < ∞).
    pub fn satisfies_convergence_conditions(&self) -> bool {
        match *self {
            StepSchedule::Constant { .. } => false,
            StepSchedule::Polynomial { b, .. } => b > 0.5 && b <= 1.0,
        }
    }

    /// The paper's PSGLD setting (a = 0.01, b = 0.51).
    pub fn paper_psgld() -> Self {
        StepSchedule::Polynomial { a: 0.01, b: 0.51 }
    }

    /// The paper's SGLD setting (a = 1, b = 0.51).
    pub fn paper_sgld() -> Self {
        StepSchedule::Polynomial { a: 1.0, b: 0.51 }
    }

    /// The paper's LD setting (constant ε). The reported 0.2 assumes the
    /// authors' gradient normalisation; experiments override per run.
    pub fn paper_ld(eps: f64) -> Self {
        StepSchedule::Constant { eps }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            StepSchedule::Constant { eps } => Json::obj(vec![
                ("kind", Json::str("constant")),
                ("eps", Json::num(eps)),
            ]),
            StepSchedule::Polynomial { a, b } => Json::obj(vec![
                ("kind", Json::str("polynomial")),
                ("a", Json::num(a)),
                ("b", Json::num(b)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        match j.field("kind")?.as_str()? {
            "constant" => Ok(StepSchedule::Constant { eps: j.field("eps")?.as_f64()? }),
            "polynomial" => Ok(StepSchedule::Polynomial {
                a: j.field("a")?.as_f64()?,
                b: j.field("b")?.as_f64()?,
            }),
            other => Err(Error::Config(format!("unknown step kind '{other}'"))),
        }
    }
}

fn schedule_to_json(s: PartSchedule) -> Json {
    Json::str(match s {
        PartSchedule::Cyclic => "cyclic",
        PartSchedule::RandomShift => "random_shift",
        PartSchedule::RandomPerm => "random_perm",
    })
}

fn schedule_from_json(j: &Json) -> Result<PartSchedule> {
    match j.as_str()? {
        "cyclic" => Ok(PartSchedule::Cyclic),
        "random_shift" => Ok(PartSchedule::RandomShift),
        "random_perm" => Ok(PartSchedule::RandomPerm),
        other => Err(Error::Config(format!("unknown schedule '{other}'"))),
    }
}

fn model_to_json(m: &NmfModel) -> Json {
    Json::obj(vec![
        ("k", Json::num(m.k as f64)),
        ("beta", Json::num(m.beta as f64)),
        ("phi", Json::num(m.phi as f64)),
        ("lam_w", Json::num(m.lam_w as f64)),
        ("lam_h", Json::num(m.lam_h as f64)),
        ("mirror", Json::Bool(m.mirror)),
    ])
}

fn model_from_json(j: &Json) -> Result<NmfModel> {
    Ok(NmfModel {
        k: j.field("k")?.as_usize()?,
        beta: j.field("beta")?.as_f64()? as f32,
        phi: j.field("phi")?.as_f64()? as f32,
        lam_w: j.field("lam_w")?.as_f64()? as f32,
        lam_h: j.field("lam_h")?.as_f64()? as f32,
        mirror: j.field("mirror")?.as_bool()?,
    })
}

/// Settings of one sampling run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Total iterations T (samples generated).
    pub t_total: u64,
    /// Burn-in iterations discarded from posterior summaries.
    pub burn_in: u64,
    /// Keep every `thin`-th sample in collected statistics.
    pub thin: u64,
    /// Step-size schedule.
    pub step: StepSchedule,
    /// How often (iterations) to record the monitor value; monitors are
    /// excluded from per-iteration timing.
    pub monitor_every: u64,
    /// Part schedule (PSGLD-family only).
    pub schedule: PartSchedule,
}

impl RunConfig {
    /// Small-run defaults for examples/tests.
    pub fn quick(t_total: u64) -> Self {
        RunConfig {
            t_total,
            burn_in: t_total / 2,
            thin: 1,
            step: StepSchedule::paper_psgld(),
            monitor_every: (t_total / 100).max(1),
            schedule: PartSchedule::Cyclic,
        }
    }

    pub fn with_step(mut self, step: StepSchedule) -> Self {
        self.step = step;
        self
    }

    pub fn with_schedule(mut self, schedule: PartSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn with_monitor_every(mut self, every: u64) -> Self {
        self.monitor_every = every.max(1);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.t_total == 0 {
            return Err(Error::Config("t_total must be positive".into()));
        }
        if self.burn_in >= self.t_total {
            return Err(Error::Config(format!(
                "burn_in {} >= t_total {}",
                self.burn_in, self.t_total
            )));
        }
        if self.thin == 0 || self.monitor_every == 0 {
            return Err(Error::Config("thin/monitor_every must be >= 1".into()));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_total", Json::num(self.t_total as f64)),
            ("burn_in", Json::num(self.burn_in as f64)),
            ("thin", Json::num(self.thin as f64)),
            ("step", self.step.to_json()),
            ("monitor_every", Json::num(self.monitor_every as f64)),
            ("schedule", schedule_to_json(self.schedule)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(RunConfig {
            t_total: j.field("t_total")?.as_u64()?,
            burn_in: j.field("burn_in")?.as_u64()?,
            thin: j.field("thin")?.as_u64()?,
            step: StepSchedule::from_json(j.field("step")?)?,
            monitor_every: j.field("monitor_every")?.as_u64()?,
            schedule: schedule_from_json(j.field("schedule")?)?,
        })
    }
}

/// Knobs of the asynchronous (fault-injecting) cluster executor:
/// bounded staleness, checkpointing cadence, and the retry policy for
/// dropped ring messages. See `cluster/async_sim.rs` for semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncClusterConfig {
    /// Staleness bound: a node may proceed with an `H` block at most
    /// `tau` iterations stale; past the bound it blocks until the ring
    /// hand-off arrives. `tau = 0` is fully synchronous semantics.
    pub tau: u64,
    /// Take a consistent checkpoint every `checkpoint_every` iterations
    /// (0 disables checkpointing; crashes then roll back to iteration 0).
    pub checkpoint_every: u64,
    /// Directory for on-disk checkpoints; `None` keeps checkpoints in
    /// memory only (still sufficient for crash recovery in-simulation).
    pub checkpoint_dir: Option<String>,
    /// Virtual seconds before an unacknowledged ring message is
    /// retransmitted.
    pub msg_timeout_s: f64,
    /// Multiplicative backoff applied to the timeout per retry.
    pub retry_backoff: f64,
    /// Retransmissions allowed before the run fails loudly.
    pub max_retries: u32,
    /// Virtual seconds a crashed node takes to come back up.
    pub restart_delay_s: f64,
}

impl Default for AsyncClusterConfig {
    fn default() -> Self {
        AsyncClusterConfig {
            tau: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            msg_timeout_s: 5e-3,
            retry_backoff: 2.0,
            max_retries: 16,
            restart_delay_s: 0.5,
        }
    }
}

impl AsyncClusterConfig {
    pub fn with_tau(mut self, tau: u64) -> Self {
        self.tau = tau;
        self
    }

    pub fn with_checkpoint_every(mut self, every: u64) -> Self {
        self.checkpoint_every = every;
        self
    }

    pub fn with_checkpoint_dir(mut self, dir: impl Into<String>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.msg_timeout_s > 0.0 && self.msg_timeout_s.is_finite()) {
            return Err(Error::Config(format!(
                "msg_timeout_s must be positive and finite, got {}",
                self.msg_timeout_s
            )));
        }
        if !(self.retry_backoff >= 1.0 && self.retry_backoff.is_finite()) {
            return Err(Error::Config(format!(
                "retry_backoff must be >= 1 and finite, got {}",
                self.retry_backoff
            )));
        }
        if !(self.restart_delay_s >= 0.0 && self.restart_delay_s.is_finite()) {
            return Err(Error::Config(format!(
                "restart_delay_s must be >= 0 and finite, got {}",
                self.restart_delay_s
            )));
        }
        if self.max_retries == 0 {
            return Err(Error::Config(
                "max_retries must be >= 1 (a dropped message would hang otherwise)".into(),
            ));
        }
        if self.checkpoint_dir.is_some() && self.checkpoint_every == 0 {
            return Err(Error::Config(
                "checkpoint_dir is set but checkpoint_every is 0; set checkpoint_every >= 1"
                    .into(),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tau", Json::num(self.tau as f64)),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            (
                "checkpoint_dir",
                match &self.checkpoint_dir {
                    Some(d) => Json::str(d.clone()),
                    None => Json::Null,
                },
            ),
            ("msg_timeout_s", Json::num(self.msg_timeout_s)),
            ("retry_backoff", Json::num(self.retry_backoff)),
            ("max_retries", Json::num(self.max_retries as f64)),
            ("restart_delay_s", Json::num(self.restart_delay_s)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let checkpoint_dir = match j.field("checkpoint_dir")? {
            Json::Null => None,
            other => Some(other.as_str()?.to_string()),
        };
        Ok(AsyncClusterConfig {
            tau: j.field("tau")?.as_u64()?,
            checkpoint_every: j.field("checkpoint_every")?.as_u64()?,
            checkpoint_dir,
            msg_timeout_s: j.field("msg_timeout_s")?.as_f64()?,
            retry_backoff: j.field("retry_backoff")?.as_f64()?,
            max_retries: j.field("max_retries")?.as_u64()? as u32,
            restart_delay_s: j.field("restart_delay_s")?.as_f64()?,
        })
    }
}

/// A full experiment description (what the CLI consumes).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub model: ModelConfig,
    pub run: RunConfig,
    /// Grid size B (PSGLD / DSGD / cluster families).
    pub b: usize,
    /// RNG seed.
    pub seed: u64,
    /// Output directory for CSVs.
    pub outdir: String,
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("model", model_to_json(&self.model)),
            ("run", self.run.to_json()),
            ("b", Json::num(self.b as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("outdir", Json::str(self.outdir.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ExperimentConfig {
            name: j.field("name")?.as_str()?.to_string(),
            model: model_from_json(j.field("model")?)?,
            run: RunConfig::from_json(j.field("run")?)?,
            b: j.field("b")?.as_usize()?,
            seed: j.field("seed")?.as_u64()?,
            outdir: j.field("outdir")?.as_str()?.to_string(),
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polynomial_step_decays() {
        let s = StepSchedule::paper_psgld();
        assert!(s.eps(1) > s.eps(10));
        assert!(s.eps(10) > s.eps(1000));
        assert!(s.satisfies_convergence_conditions());
        assert!(!StepSchedule::Constant { eps: 0.1 }.satisfies_convergence_conditions());
        assert!(!StepSchedule::Polynomial { a: 1.0, b: 0.5 }
            .satisfies_convergence_conditions());
    }

    #[test]
    fn step_t_zero_safe() {
        let s = StepSchedule::paper_sgld();
        assert!(s.eps(0).is_finite());
        assert_eq!(s.eps(0), s.eps(1));
    }

    #[test]
    fn step_json_roundtrip() {
        for s in [StepSchedule::paper_psgld(), StepSchedule::paper_ld(0.2)] {
            let back = StepSchedule::from_json(&s.to_json()).unwrap();
            assert_eq!(s, back);
        }
    }

    #[test]
    fn run_config_validation() {
        assert!(RunConfig::quick(100).validate().is_ok());
        let mut bad = RunConfig::quick(100);
        bad.burn_in = 100;
        assert!(bad.validate().is_err());
        let mut bad2 = RunConfig::quick(100);
        bad2.thin = 0;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn experiment_config_json_roundtrip() {
        let cfg = ExperimentConfig {
            name: "fig2a".into(),
            model: ModelConfig::poisson(32),
            run: RunConfig::quick(1000).with_schedule(PartSchedule::RandomShift),
            b: 8,
            seed: 42,
            outdir: "results".into(),
        };
        let dir = std::env::temp_dir().join("psgld_cfg_test");
        let path = dir.join("cfg.json");
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.b, 8);
        assert_eq!(back.run.schedule, PartSchedule::RandomShift);
        assert_eq!(back.run.step, cfg.run.step);
    }

    #[test]
    fn async_cluster_config_roundtrip_and_validation() {
        let cfg = AsyncClusterConfig::default()
            .with_tau(4)
            .with_checkpoint_every(25)
            .with_checkpoint_dir("/tmp/ckpts");
        assert!(cfg.validate().is_ok());
        let back = AsyncClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // None dir survives the roundtrip as Null
        let plain = AsyncClusterConfig::default();
        assert!(plain.validate().is_ok());
        let back = AsyncClusterConfig::from_json(&plain.to_json()).unwrap();
        assert_eq!(back, plain);

        let bad = AsyncClusterConfig { msg_timeout_s: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AsyncClusterConfig { retry_backoff: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AsyncClusterConfig { max_retries: 0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("hang"));
        let bad = AsyncClusterConfig::default().with_checkpoint_dir("x");
        assert!(bad.validate().unwrap_err().to_string().contains("checkpoint_every"));
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(StepSchedule::from_json(&Json::parse(r#"{"kind":"nope"}"#).unwrap()).is_err());
        assert!(schedule_from_json(&Json::str("bogus")).is_err());
    }
}
