//! Bench / health baseline comparison behind the `check-regression`
//! CLI subcommand.
//!
//! Baselines are the committed `baselines/BENCH_*.json` (rows from the
//! bench harnesses, keyed by `name` with an `ops_per_s` metric, or by
//! `tau`/`crash_rate` with `iters_per_vsec` for the fault sweep) plus
//! optional `health_summary.json` gauges. A metric regresses when the
//! current value drops below `baseline * (1 - tol_frac)`; higher is
//! always better for every compared metric.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::Json;
use crate::{Error, Result};

/// One metric that fell below its tolerance band.
#[derive(Clone, Debug)]
pub struct RegressionFinding {
    pub file: String,
    pub key: String,
    pub baseline: f64,
    pub current: f64,
}

impl RegressionFinding {
    /// current / baseline (both finite and positive by construction).
    pub fn ratio(&self) -> f64 {
        self.current / self.baseline
    }
}

/// Outcome of a baseline comparison.
#[derive(Clone, Debug, Default)]
pub struct RegressionReport {
    /// Metrics present in both baseline and current.
    pub compared: usize,
    pub regressions: Vec<RegressionFinding>,
    /// Baseline entries with no counterpart in the current run.
    pub skipped: Vec<String>,
}

impl RegressionReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare `current` against `baseline` (two files, or two directories
/// paired by file name) with relative tolerance `tol_frac` in [0, 1).
pub fn check_regression(
    baseline: &Path,
    current: &Path,
    tol_frac: f64,
) -> Result<RegressionReport> {
    if !(0.0..1.0).contains(&tol_frac) {
        return Err(Error::Config(format!(
            "tolerance fraction {tol_frac} outside [0, 1)"
        )));
    }
    let mut report = RegressionReport::default();
    if baseline.is_dir() {
        if !current.is_dir() {
            return Err(Error::Config(format!(
                "baseline {} is a directory but current {} is not",
                baseline.display(),
                current.display()
            )));
        }
        let mut names: Vec<String> = std::fs::read_dir(baseline)?
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| comparable_file(n))
            .collect();
        names.sort();
        if names.is_empty() {
            return Err(Error::Config(format!(
                "baseline directory {} has no BENCH_*.json or health/obs summaries",
                baseline.display()
            )));
        }
        for name in names {
            let cur = current.join(&name);
            if cur.is_file() {
                compare_file(&mut report, &name, &baseline.join(&name), &cur, tol_frac)?;
            } else {
                report.skipped.push(format!("{name}: missing from current run"));
            }
        }
    } else {
        let name = baseline
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("baseline")
            .to_string();
        compare_file(&mut report, &name, baseline, current, tol_frac)?;
    }
    Ok(report)
}

fn comparable_file(name: &str) -> bool {
    (name.starts_with("BENCH_") && name.ends_with(".json"))
        || name == "health_summary.json"
        || name == "obs_summary.json"
}

fn parse_file(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
    Json::parse(&text).map_err(|e| Error::Config(format!("{}: {e}", path.display())))
}

/// Extract (key, value) for one bench row; `None` for rows without a
/// recognised throughput metric.
fn row_entry(row: &Json) -> Option<(String, f64)> {
    if let Some(name) = row.field_opt("name").and_then(|n| n.as_str().ok()) {
        let v = row.field_opt("ops_per_s")?.as_f64().ok()?;
        return Some((format!("{name}:ops_per_s"), v));
    }
    let tau = row.field_opt("tau")?.as_f64().ok()?;
    let rate = row.field_opt("crash_rate")?.as_f64().ok()?;
    let v = row.field_opt("iters_per_vsec")?.as_f64().ok()?;
    Some((format!("tau={tau},crash_rate={rate}:iters_per_vsec"), v))
}

/// All comparable metrics in one parsed file, keyed for pairing.
fn metrics_of(doc: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    match doc {
        Json::Arr(rows) => {
            for row in rows {
                if let Some((k, v)) = row_entry(row) {
                    out.insert(k, v);
                }
            }
        }
        Json::Obj(_) => {
            // health_summary.json: ESS/sec gauge, when present.
            if let Some(v) = doc
                .field_opt("gauges")
                .and_then(|g| g.field_opt("ess_per_sec"))
                .and_then(|v| v.as_f64().ok())
            {
                out.insert("gauges.ess_per_sec".to_string(), v);
            }
        }
        _ => {}
    }
    out
}

fn compare_file(
    report: &mut RegressionReport,
    name: &str,
    baseline: &Path,
    current: &Path,
    tol_frac: f64,
) -> Result<()> {
    let base = metrics_of(&parse_file(baseline)?);
    let cur = metrics_of(&parse_file(current)?);
    if base.is_empty() {
        report.skipped.push(format!("{name}: no comparable metrics in baseline"));
        return Ok(());
    }
    for (key, &bv) in &base {
        match cur.get(key) {
            Some(&cv) if bv.is_finite() && cv.is_finite() && bv > 0.0 => {
                report.compared += 1;
                if cv < bv * (1.0 - tol_frac) {
                    report.regressions.push(RegressionFinding {
                        file: name.to_string(),
                        key: key.clone(),
                        baseline: bv,
                        current: cv,
                    });
                }
            }
            Some(_) => {
                report.skipped.push(format!("{name}:{key}: non-finite value"));
            }
            None => {
                report.skipped.push(format!("{name}:{key}: missing from current run"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write(dir: &Path, name: &str, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join(name), text).unwrap();
    }

    const BENCH: &str = r#"[
        {"name":"dense_grads/K=8","ns_per_iter":10.0,"ops_per_s":1000.0,"unit":"entries","threads":1},
        {"name":"sgld_apply/16384","ns_per_iter":5.0,"ops_per_s":2000.0,"unit":"entries","threads":1}
    ]"#;

    const FAULT: &str = r#"[
        {"tau":0,"crash_rate":0.0,"iters_per_vsec":50.0,"holdout_loglik":-1.0},
        {"tau":4,"crash_rate":0.02,"iters_per_vsec":40.0,"holdout_loglik":-1.0}
    ]"#;

    #[test]
    fn identical_dirs_pass() {
        let base = std::env::temp_dir().join("psgld_reg_base_a");
        let cur = std::env::temp_dir().join("psgld_reg_cur_a");
        for d in [&base, &cur] {
            write(d, "BENCH_kernels.json", BENCH);
            write(d, "BENCH_fault.json", FAULT);
        }
        let rep = check_regression(&base, &cur, 0.2).unwrap();
        assert!(rep.passed(), "regressions: {:?}", rep.regressions);
        assert_eq!(rep.compared, 4);
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&cur);
    }

    #[test]
    fn degraded_throughput_fails() {
        let base = std::env::temp_dir().join("psgld_reg_base_b");
        let cur = std::env::temp_dir().join("psgld_reg_cur_b");
        write(&base, "BENCH_kernels.json", BENCH);
        let degraded = BENCH.replace("1000.0", "100.0");
        write(&cur, "BENCH_kernels.json", &degraded);
        let rep = check_regression(&base, &cur, 0.5).unwrap();
        assert!(!rep.passed());
        assert_eq!(rep.regressions.len(), 1);
        let f = &rep.regressions[0];
        assert_eq!(f.key, "dense_grads/K=8:ops_per_s");
        assert!((f.ratio() - 0.1).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&cur);
    }

    #[test]
    fn within_tolerance_passes() {
        let base = std::env::temp_dir().join("psgld_reg_base_c");
        let cur = std::env::temp_dir().join("psgld_reg_cur_c");
        write(&base, "BENCH_fault.json", FAULT);
        write(&cur, "BENCH_fault.json", &FAULT.replace("40.0", "35.0"));
        let rep = check_regression(&base, &cur, 0.2).unwrap();
        assert!(rep.passed(), "12.5% drop within 20% band: {:?}", rep.regressions);
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&cur);
    }

    #[test]
    fn health_gauges_compare() {
        let base = std::env::temp_dir().join("psgld_reg_base_d");
        let cur = std::env::temp_dir().join("psgld_reg_cur_d");
        write(
            &base,
            "health_summary.json",
            r#"{"alerts_total":0,"gauges":{"ess_per_sec":10.0}}"#,
        );
        write(
            &cur,
            "health_summary.json",
            r#"{"alerts_total":0,"gauges":{"ess_per_sec":2.0}}"#,
        );
        let rep = check_regression(&base, &cur, 0.5).unwrap();
        assert_eq!(rep.regressions.len(), 1);
        assert_eq!(rep.regressions[0].key, "gauges.ess_per_sec");
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&cur);
    }

    #[test]
    fn missing_current_file_is_skipped_not_failed() {
        let base = std::env::temp_dir().join("psgld_reg_base_e");
        let cur = std::env::temp_dir().join("psgld_reg_cur_e");
        write(&base, "BENCH_kernels.json", BENCH);
        write(&base, "BENCH_fig5.json", BENCH);
        write(&cur, "BENCH_kernels.json", BENCH);
        let rep = check_regression(&base, &cur, 0.2).unwrap();
        assert!(rep.passed());
        assert!(rep.skipped.iter().any(|s| s.contains("BENCH_fig5.json")));
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&cur);
    }

    #[test]
    fn bad_tolerance_rejected() {
        let p = Path::new("/nonexistent");
        assert!(check_regression(p, p, 1.5).is_err());
    }
}
