//! OpenMetrics text exposition of the obs-metrics snapshot merged with
//! the monitor's health gauges, plus a minimal lint used by tests and
//! CI to keep the output scrape-compatible.
//!
//! Format reference: OpenMetrics 1.0 text format. We emit only the
//! subset we need — `# TYPE` metadata, counter samples with the
//! `_total` suffix, labelled gauges, and the mandatory `# EOF`
//! terminator — and the lint checks exactly that subset.

use std::fmt::Write as _;

use crate::obs::{self, Counter, Phase};
use crate::{Error, Result};

/// Render the merged obs + health snapshot as OpenMetrics text.
///
/// Non-finite gauge values are omitted rather than serialised: a
/// missing sample is meaningful to a scraper, a `NaN` is noise.
pub fn render_openmetrics() -> String {
    let snap = obs::snapshot();
    let health = super::health_snapshot();
    let mut out = String::with_capacity(4096);

    // --- obs counters ---
    for c in Counter::ALL {
        let _ = writeln!(out, "# TYPE pallas_{} counter", c.name());
        let _ = writeln!(out, "pallas_{}_total {}", c.name(), snap.counter(c));
    }

    // --- per-phase wall time and span counts ---
    let _ = writeln!(out, "# TYPE pallas_phase_seconds counter");
    for p in Phase::ALL {
        let _ = writeln!(
            out,
            "pallas_phase_seconds_total{{phase=\"{}\"}} {}",
            p.name(),
            snap.phase_seconds(p)
        );
    }
    let _ = writeln!(out, "# TYPE pallas_phase_spans counter");
    for p in Phase::ALL {
        let _ = writeln!(
            out,
            "pallas_phase_spans_total{{phase=\"{}\"}} {}",
            p.name(),
            snap.phase_count[p.idx()]
        );
    }
    let _ = writeln!(out, "# TYPE pallas_phase_duration_ns gauge");
    for p in Phase::ALL {
        if snap.phase_count[p.idx()] == 0 {
            continue;
        }
        for q in [0.5, 0.99] {
            let v = snap.quantile_ns(p, q);
            if v.is_finite() {
                let _ = writeln!(
                    out,
                    "pallas_phase_duration_ns{{phase=\"{}\",quantile=\"{q}\"}} {v}",
                    p.name()
                );
            }
        }
    }

    // --- monitor gauges ---
    let _ = writeln!(out, "# TYPE pallas_health_alerts counter");
    for (sev, n) in [
        ("info", health.alerts_info),
        ("warn", health.alerts_warn),
        ("critical", health.alerts_critical),
    ] {
        let _ = writeln!(out, "pallas_health_alerts_total{{severity=\"{sev}\"}} {n}");
    }
    let _ = writeln!(out, "# TYPE pallas_health_chains gauge");
    let _ = writeln!(out, "pallas_health_chains {}", health.chains.len());
    if let Some(rhat) = health.split_rhat {
        if rhat.is_finite() {
            let _ = writeln!(out, "# TYPE pallas_health_split_rhat gauge");
            let _ = writeln!(out, "pallas_health_split_rhat {rhat}");
        }
    }
    let _ = writeln!(out, "# TYPE pallas_health_samples counter");
    for c in &health.chains {
        let _ = writeln!(
            out,
            "pallas_health_samples_total{{chain=\"{}\"}} {}",
            c.chain, c.samples
        );
    }
    let _ = writeln!(out, "# TYPE pallas_health_ess_per_sec gauge");
    for c in &health.chains {
        if c.ess_per_sec.is_finite() {
            let _ = writeln!(
                out,
                "pallas_health_ess_per_sec{{chain=\"{}\"}} {}",
                c.chain, c.ess_per_sec
            );
        }
    }
    let _ = writeln!(out, "# TYPE pallas_health_value gauge");
    for c in &health.chains {
        for (stat, v) in
            [("mean", c.mean), ("q05", c.q05), ("q50", c.q50), ("q95", c.q95)]
        {
            if v.is_finite() {
                let _ = writeln!(
                    out,
                    "pallas_health_value{{chain=\"{}\",stat=\"{stat}\"}} {v}",
                    c.chain
                );
            }
        }
    }
    let _ = writeln!(out, "# TYPE pallas_health_node_stall_ratio gauge");
    for n in &health.nodes {
        if n.stall_ratio.is_finite() {
            let _ = writeln!(
                out,
                "pallas_health_node_stall_ratio{{node=\"{}\"}} {}",
                n.node, n.stall_ratio
            );
        }
    }
    let _ = writeln!(out, "# TYPE pallas_health_node_staleness_max gauge");
    for n in &health.nodes {
        let _ = writeln!(
            out,
            "pallas_health_node_staleness_max{{node=\"{}\"}} {}",
            n.node, n.max_staleness
        );
    }

    out.push_str("# EOF\n");
    out
}

fn is_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Minimal OpenMetrics lint: every sample's family must be declared by
/// a preceding `# TYPE` line (directly or via the `_total` suffix),
/// names must match the metric-name charset, values must parse as
/// floats, and the exposition must end with `# EOF`.
pub fn lint_openmetrics(text: &str) -> Result<()> {
    let mut families: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    let mut samples = 0usize;
    let mut saw_eof = false;
    for (i, line) in text.lines().enumerate() {
        let fail = |msg: String| Err(Error::Config(format!("openmetrics line {}: {msg}", i + 1)));
        if saw_eof {
            return fail("content after # EOF".to_string());
        }
        if line.is_empty() {
            return fail("empty line".to_string());
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if rest == "EOF" {
                saw_eof = true;
                continue;
            }
            let mut parts = rest.splitn(3, ' ');
            let keyword = parts.next().unwrap_or("");
            match keyword {
                "TYPE" => {
                    let Some(name) = parts.next() else {
                        return fail("# TYPE without a family name".to_string());
                    };
                    let Some(kind) = parts.next() else {
                        return fail("# TYPE without a type".to_string());
                    };
                    if !is_metric_name(name) {
                        return fail(format!("bad family name {name:?}"));
                    }
                    if !matches!(
                        kind,
                        "counter" | "gauge" | "histogram" | "summary" | "info" | "unknown"
                    ) {
                        return fail(format!("unknown metric type {kind:?}"));
                    }
                    families.insert(name.to_string());
                }
                "HELP" | "UNIT" => {
                    let Some(name) = parts.next() else {
                        return fail(format!("# {keyword} without a family name"));
                    };
                    if !is_metric_name(name) {
                        return fail(format!("bad family name {name:?}"));
                    }
                }
                other => return fail(format!("unknown comment keyword {other:?}")),
            }
            continue;
        }
        // Sample line: name[{labels}] value
        let Some(name_end) = line.find(|c: char| c == '{' || c == ' ') else {
            return fail("sample without a value".to_string());
        };
        let name = &line[..name_end];
        if !is_metric_name(name) {
            return fail(format!("bad metric name {name:?}"));
        }
        let base = name.strip_suffix("_total").unwrap_or(name);
        if !families.contains(name) && !families.contains(base) {
            return fail(format!("sample {name:?} precedes its # TYPE declaration"));
        }
        let after = &line[name_end..];
        let value_part = if let Some(stripped) = after.strip_prefix('{') {
            let Some(close) = stripped.find('}') else {
                return fail("unterminated label set".to_string());
            };
            if stripped[..close].matches('"').count() % 2 != 0 {
                return fail("unbalanced quotes in label set".to_string());
            }
            &stripped[close + 1..]
        } else {
            after
        };
        let Some(value) = value_part.split_whitespace().next() else {
            return fail("sample without a value".to_string());
        };
        if value.parse::<f64>().is_err() {
            return fail(format!("sample value {value:?} is not a float"));
        }
        samples += 1;
    }
    if !saw_eof {
        return Err(Error::Config(
            "openmetrics exposition missing the # EOF terminator".to_string(),
        ));
    }
    if samples == 0 {
        return Err(Error::Config(
            "openmetrics exposition contains no samples".to_string(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_accepts_minimal_exposition() {
        let text = "# TYPE pallas_steps counter\n\
                    pallas_steps_total 42\n\
                    # TYPE x gauge\n\
                    x{chain=\"0\",stat=\"mean\"} -1.25e3\n\
                    # EOF\n";
        lint_openmetrics(text).unwrap();
    }

    #[test]
    fn lint_rejects_missing_eof() {
        let text = "# TYPE a counter\na_total 1\n";
        assert!(lint_openmetrics(text).is_err());
    }

    #[test]
    fn lint_rejects_undeclared_sample() {
        let text = "# TYPE a counter\nb_total 1\n# EOF\n";
        assert!(lint_openmetrics(text).is_err());
    }

    #[test]
    fn lint_rejects_bad_value_and_name() {
        assert!(lint_openmetrics("# TYPE a gauge\na forty\n# EOF\n").is_err());
        assert!(lint_openmetrics("# TYPE 9bad gauge\n9bad 1\n# EOF\n").is_err());
        assert!(lint_openmetrics("# TYPE a gauge\na 1\nx\n# EOF\n").is_err());
        assert!(lint_openmetrics("# TYPE a gauge\n# EOF\n").is_err(), "no samples");
        assert!(lint_openmetrics("# TYPE a gauge\na 1\n# EOF\nz 1\n").is_err());
    }

    #[test]
    fn render_lints_clean() {
        let _g = crate::obs::test_guard();
        crate::obs::set_level_override(Some(crate::obs::ObsLevel::Counters));
        crate::obs::reset();
        crate::monitor::reset();
        crate::obs::counter_add(Counter::Steps, 3);
        crate::monitor::with_chain(0, || {
            for t in 1..=20u64 {
                crate::monitor::observe_sample(t, t as f64 * 0.01, (t % 5) as f64);
            }
        });
        let text = render_openmetrics();
        lint_openmetrics(&text).unwrap();
        assert!(text.contains("pallas_steps_total 3"));
        assert!(text.contains("pallas_health_samples_total{chain=\"0\"} 20"));
        assert!(text.ends_with("# EOF\n"));
        crate::monitor::reset();
        crate::obs::reset();
        crate::obs::set_level_override(None);
    }
}
