//! Streaming statistics with O(1) (or bounded) memory per statistic.
//!
//! Everything here is a plain accumulator: no global state, no locking,
//! no interaction with the chain RNG. The windowed estimators reuse the
//! batch implementations in [`crate::metrics::diagnostics`] over their
//! bounded window so the online numbers agree with the post-hoc
//! diagnostics bit-for-bit whenever the window covers the full stream
//! (pinned by `tests/monitor.rs`).

use std::cmp::Ordering;

use crate::metrics::diagnostics::{gelman_rubin, integrated_autocorr_time};
use crate::rng::Rng;

/// Welford's online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (NaN before the first observation).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance (NaN until two observations).
    pub fn var_sample(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Population variance (NaN before the first observation).
    pub fn var_pop(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample standard deviation (NaN until two observations).
    pub fn sd(&self) -> f64 {
        self.var_sample().sqrt()
    }
}

/// Online across-chain potential scale reduction factor.
///
/// Keeps one [`Welford`] per chain; `rhat()` evaluates the classic
/// Gelman–Rubin statistic from the per-chain moments alone, which is
/// exactly the batch formula when every chain has seen the same number
/// of samples (the batch code trims to the minimum length instead).
#[derive(Clone, Debug, Default)]
pub struct OnlineRhat {
    chains: Vec<Welford>,
}

impl OnlineRhat {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation from `chain` (chains are created on first
    /// use, so indices may arrive in any order).
    pub fn push(&mut self, chain: usize, x: f64) {
        if chain >= self.chains.len() {
            self.chains.resize_with(chain + 1, Welford::new);
        }
        self.chains[chain].push(x);
    }

    pub fn n_chains(&self) -> usize {
        self.chains.len()
    }

    /// R̂ from the running moments, or `None` until there are at least
    /// two chains with at least four samples each and equal counts
    /// (unequal counts would silently diverge from the batch estimate).
    pub fn rhat(&self) -> Option<f64> {
        let m = self.chains.len();
        if m < 2 {
            return None;
        }
        let n = self.chains[0].count();
        if n < 4 || self.chains.iter().any(|c| c.count() != n) {
            return None;
        }
        let nf = n as f64;
        let grand = self.chains.iter().map(|c| c.mean()).sum::<f64>() / m as f64;
        let b = nf / (m - 1) as f64
            * self.chains.iter().map(|c| (c.mean() - grand).powi(2)).sum::<f64>();
        let w = self.chains.iter().map(|c| c.var_sample()).sum::<f64>() / m as f64;
        if w == 0.0 {
            return Some(1.0);
        }
        let var_plus = (nf - 1.0) / nf * w + b / nf;
        Some((var_plus / w).sqrt())
    }
}

/// Fixed-capacity ring buffer over the most recent observations.
#[derive(Clone, Debug)]
pub struct RingWindow {
    buf: Vec<f64>,
    cap: usize,
    /// Index of the oldest element once the buffer is full.
    head: usize,
}

impl RingWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring window needs capacity >= 1");
        RingWindow { buf: Vec::new(), cap, head: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Oldest element still in the window.
    pub fn front(&self) -> Option<f64> {
        self.buf.get(self.head).copied()
    }

    /// Window contents in arrival order (oldest first).
    pub fn to_vec(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

/// Integrated autocorrelation time of the window contents — the batch
/// Geyer initial-positive-sequence estimator applied to the (ordered)
/// window, so it matches `integrated_autocorr_time` exactly while the
/// window still covers the whole stream.
pub fn windowed_iat(w: &RingWindow) -> f64 {
    integrated_autocorr_time(&w.to_vec())
}

/// Split-R̂ of a single stream: first half vs second half of the
/// window, through the batch [`gelman_rubin`]. `None` until each half
/// has at least four samples (the batch code's minimum).
pub fn split_rhat_window(w: &RingWindow) -> Option<f64> {
    let v = w.to_vec();
    let half = v.len() / 2;
    if half < 4 {
        return None;
    }
    let first = v[..half].to_vec();
    let second = v[v.len() - half..].to_vec();
    Some(gelman_rubin(&[first, second]))
}

/// Reservoir-sampled quantile estimator (Vitter's Algorithm R) with a
/// fixed-size reservoir and its own derived RNG stream — it never
/// touches the chain RNG, so sampling output is unaffected.
#[derive(Clone, Debug)]
pub struct ReservoirQuantiles {
    res: Vec<f64>,
    cap: usize,
    seen: u64,
    rng: Rng,
}

impl ReservoirQuantiles {
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap > 0, "reservoir needs capacity >= 1");
        ReservoirQuantiles {
            res: Vec::new(),
            cap,
            seen: 0,
            // "moni" tag keeps this stream disjoint from sampler streams
            rng: Rng::derive(seed, &[0x6d6f_6e69]),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.res.len() < self.cap {
            self.res.push(x);
        } else {
            let j = self.rng.next_below(self.seen);
            if (j as usize) < self.cap {
                self.res[j as usize] = x;
            }
        }
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Empirical `q`-quantile of the reservoir (NaN while empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.res.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.res.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));
        let pos = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[pos.min(sorted.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_two_pass() {
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 19) as f64 * 0.5 - 3.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var_sample() - var).abs() < 1e-12);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn welford_edge_counts() {
        let mut w = Welford::new();
        assert!(w.mean().is_nan());
        w.push(2.0);
        assert_eq!(w.mean(), 2.0);
        assert!(w.var_sample().is_nan());
        assert_eq!(w.var_pop(), 0.0);
    }

    #[test]
    fn online_rhat_matches_batch() {
        let mut rng = Rng::seed_from(7);
        let chains: Vec<Vec<f64>> = (0..3)
            .map(|c| (0..200).map(|_| rng.next_f64() + c as f64 * 0.01).collect())
            .collect();
        let mut online = OnlineRhat::new();
        for (c, chain) in chains.iter().enumerate() {
            for &x in chain {
                online.push(c, x);
            }
        }
        let batch = gelman_rubin(&chains);
        let got = online.rhat().expect("rhat available");
        assert!((got - batch).abs() < 1e-12, "online {got} vs batch {batch}");
    }

    #[test]
    fn online_rhat_requires_equal_counts() {
        let mut online = OnlineRhat::new();
        for i in 0..10 {
            online.push(0, i as f64);
        }
        assert_eq!(online.rhat(), None, "single chain");
        for i in 0..9 {
            online.push(1, i as f64);
        }
        assert_eq!(online.rhat(), None, "unequal counts");
        online.push(1, 9.0);
        assert!(online.rhat().is_some());
    }

    #[test]
    fn ring_window_wraps_in_order() {
        let mut w = RingWindow::new(4);
        for i in 0..6 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.to_vec(), vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w.front(), Some(2.0));
    }

    #[test]
    fn windowed_iat_matches_batch_when_window_covers_stream() {
        let mut rng = Rng::seed_from(11);
        let xs: Vec<f64> = (0..300).map(|_| rng.next_f64()).collect();
        let mut w = RingWindow::new(512);
        for &x in &xs {
            w.push(x);
        }
        let batch = integrated_autocorr_time(&xs);
        assert_eq!(windowed_iat(&w), batch);
    }

    #[test]
    fn split_rhat_window_is_batch_on_halves() {
        let mut rng = Rng::seed_from(13);
        let xs: Vec<f64> = (0..100).map(|_| rng.next_f64()).collect();
        let mut w = RingWindow::new(128);
        for &x in &xs {
            w.push(x);
        }
        let batch = gelman_rubin(&[xs[..50].to_vec(), xs[50..].to_vec()]);
        let got = split_rhat_window(&w).unwrap();
        assert!((got - batch).abs() < 1e-15);
    }

    #[test]
    fn reservoir_median_is_sane() {
        let mut r = ReservoirQuantiles::new(64, 99);
        for i in 0..10_000 {
            r.push((i % 1000) as f64);
        }
        let med = r.quantile(0.5);
        assert!((200.0..800.0).contains(&med), "median {med} far from 500");
        assert!(r.quantile(0.0) <= r.quantile(1.0));
        assert_eq!(r.seen(), 10_000);
    }
}
