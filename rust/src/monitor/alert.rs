//! Declarative alert rules over the streaming health statistics.
//!
//! The engine is evaluated from the monitor feed after each sample /
//! node event. Rules are data, not code: each carries its thresholds
//! and a per-(rule, subject) cooldown measured in iterations, so a
//! persistent condition fires exactly once per cooldown window and the
//! suppressed count is reported on the next firing.

use std::collections::BTreeMap;

use crate::util::Json;

/// Alert severity, ordered least to most severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Critical,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Critical => "critical",
        }
    }
}

/// One declarative health rule. All cooldowns are in iterations of the
/// subject's own clock (sampler iteration `t` for chain rules, node
/// iteration for node rules).
#[derive(Clone, Debug)]
pub enum AlertRule {
    /// The monitored scalar (loglik / RMSE) went NaN or infinite.
    NonFiniteValue { cooldown: u64 },
    /// Windowed ESS per second dropped below `floor`.
    EssPerSecBelow { floor: f64, min_samples: u64, cooldown: u64 },
    /// Split-R̂ (across chains when available, else window halves)
    /// exceeds `threshold` after `warmup_iters`.
    SplitRhatAbove { threshold: f64, warmup_iters: u64, min_samples: u64, cooldown: u64 },
    /// A node spends more than `ratio` of its virtual time stalled.
    StallTimeRatioAbove { ratio: f64, min_execs: u64, cooldown: u64 },
    /// A node ran at staleness == tau for `k` consecutive executions
    /// (only meaningful when tau > 0: the bound is actively binding).
    StalenessPinned { k: u64, cooldown: u64 },
    /// Dropped-to-sent message ratio exceeded `ratio`.
    MsgsDroppedRatioAbove { ratio: f64, min_sent: u64, cooldown: u64 },
}

impl AlertRule {
    /// Stable machine-readable rule identifier (JSONL `rule` field).
    pub fn name(&self) -> &'static str {
        match self {
            AlertRule::NonFiniteValue { .. } => "non_finite_value",
            AlertRule::EssPerSecBelow { .. } => "ess_per_sec_below",
            AlertRule::SplitRhatAbove { .. } => "split_rhat_above",
            AlertRule::StallTimeRatioAbove { .. } => "stall_time_ratio_above",
            AlertRule::StalenessPinned { .. } => "staleness_pinned",
            AlertRule::MsgsDroppedRatioAbove { .. } => "msgs_dropped_ratio",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            AlertRule::NonFiniteValue { .. } => Severity::Critical,
            AlertRule::SplitRhatAbove { .. } => Severity::Warn,
            AlertRule::EssPerSecBelow { .. } => Severity::Warn,
            AlertRule::StallTimeRatioAbove { .. } => Severity::Warn,
            AlertRule::StalenessPinned { .. } => Severity::Warn,
            AlertRule::MsgsDroppedRatioAbove { .. } => Severity::Warn,
        }
    }

    pub fn cooldown(&self) -> u64 {
        match *self {
            AlertRule::NonFiniteValue { cooldown }
            | AlertRule::EssPerSecBelow { cooldown, .. }
            | AlertRule::SplitRhatAbove { cooldown, .. }
            | AlertRule::StallTimeRatioAbove { cooldown, .. }
            | AlertRule::StalenessPinned { cooldown, .. }
            | AlertRule::MsgsDroppedRatioAbove { cooldown, .. } => cooldown,
        }
    }

    /// Conservative default rule set: guaranteed quiet on a healthy
    /// run. Chain-trend rules (`EssPerSecBelow`, `SplitRhatAbove`) are
    /// workload-specific — a short monitored transient trips them on
    /// perfectly healthy burn-in — so they ship disabled and are opted
    /// into via [`crate::monitor::set_rules`].
    pub fn default_set() -> Vec<AlertRule> {
        vec![
            AlertRule::NonFiniteValue { cooldown: 100 },
            AlertRule::StallTimeRatioAbove { ratio: 0.9, min_execs: 16, cooldown: 100 },
            AlertRule::StalenessPinned { k: 16, cooldown: 100 },
            AlertRule::MsgsDroppedRatioAbove { ratio: 0.25, min_sent: 20, cooldown: 100 },
        ]
    }
}

/// Per-sample context handed to the chain rules.
#[derive(Clone, Copy, Debug)]
pub struct SampleCtx {
    pub chain: usize,
    pub t: u64,
    pub value: f64,
    pub samples: u64,
    /// Latest windowed ESS/sec (NaN until computable).
    pub ess_per_sec: f64,
    /// Latest split-R̂ (None until enough samples).
    pub split_rhat: Option<f64>,
}

/// Per-execution context handed to the node rules.
#[derive(Clone, Copy, Debug)]
pub struct NodeCtx {
    pub node: usize,
    pub t: u64,
    pub execs: u64,
    pub staleness: u64,
    pub tau: u64,
    pub consecutive_at_tau: u64,
    /// stall / (stall + busy) virtual time (NaN until any time accrues).
    pub stall_ratio: f64,
    pub msgs_sent: u64,
    pub msgs_dropped: u64,
}

/// A fired alert, ready for JSONL serialisation.
#[derive(Clone, Debug)]
pub struct HealthEvent {
    pub severity: Severity,
    pub rule: &'static str,
    /// `chain<i>` or `node<i>`.
    pub subject: String,
    /// Iteration at which the rule fired.
    pub t: u64,
    /// Observed value that tripped the rule (NaN serialises as null).
    pub value: f64,
    /// Threshold the rule compared against.
    pub threshold: f64,
    pub message: String,
    /// Evaluations suppressed by the cooldown since the previous
    /// firing of this (rule, subject) pair.
    pub suppressed_since_last: u64,
}

impl HealthEvent {
    pub fn to_json(&self) -> Json {
        fn num(x: f64) -> Json {
            if x.is_finite() {
                Json::num(x)
            } else {
                Json::Null
            }
        }
        Json::obj(vec![
            ("schema", Json::Str("psgld-health/1".to_string())),
            ("severity", Json::Str(self.severity.name().to_string())),
            ("rule", Json::Str(self.rule.to_string())),
            ("subject", Json::Str(self.subject.clone())),
            ("t", Json::num(self.t as f64)),
            ("value", num(self.value)),
            ("threshold", num(self.threshold)),
            ("message", Json::Str(self.message.clone())),
            ("suppressed_since_last", Json::num(self.suppressed_since_last as f64)),
        ])
    }
}

/// Subject identifier: chain and node index spaces must not collide in
/// the cooldown map.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Subject {
    Chain(usize),
    Node(usize),
}

impl Subject {
    fn label(self) -> String {
        match self {
            Subject::Chain(i) => format!("chain{i}"),
            Subject::Node(i) => format!("node{i}"),
        }
    }
}

/// Evaluates rules, applies per-(rule, subject) cooldowns, and retains
/// the fired events for export.
#[derive(Clone, Debug)]
pub struct AlertEngine {
    rules: Vec<AlertRule>,
    last_fire: BTreeMap<(usize, Subject), u64>,
    suppressed: BTreeMap<(usize, Subject), u64>,
    events: Vec<HealthEvent>,
}

impl AlertEngine {
    pub fn new(rules: Vec<AlertRule>) -> Self {
        AlertEngine {
            rules,
            last_fire: BTreeMap::new(),
            suppressed: BTreeMap::new(),
            events: Vec::new(),
        }
    }

    pub fn with_default_rules() -> Self {
        Self::new(AlertRule::default_set())
    }

    pub fn set_rules(&mut self, rules: Vec<AlertRule>) {
        self.rules = rules;
        self.last_fire.clear();
        self.suppressed.clear();
    }

    pub fn rules(&self) -> &[AlertRule] {
        &self.rules
    }

    pub fn events(&self) -> &[HealthEvent] {
        &self.events
    }

    pub fn count_by_severity(&self, sev: Severity) -> usize {
        self.events.iter().filter(|e| e.severity == sev).count()
    }

    /// Evaluate the chain rules against one monitored sample. Returns
    /// the number of events fired (post-cooldown).
    pub fn eval_sample(&mut self, ctx: &SampleCtx) -> usize {
        let subject = Subject::Chain(ctx.chain);
        let mut fired = 0;
        for idx in 0..self.rules.len() {
            let rule = self.rules[idx].clone();
            match rule {
                AlertRule::NonFiniteValue { .. } => {
                    if !ctx.value.is_finite() {
                        let msg = format!(
                            "monitored value is {} at t={}",
                            ctx.value, ctx.t
                        );
                        fired += self.try_fire(idx, subject, ctx.t, ctx.value, 0.0, msg);
                    }
                }
                AlertRule::EssPerSecBelow { floor, min_samples, .. } => {
                    if ctx.samples >= min_samples
                        && ctx.ess_per_sec.is_finite()
                        && ctx.ess_per_sec < floor
                    {
                        let msg = format!(
                            "ESS/sec {:.3} below floor {floor:.3} at t={}",
                            ctx.ess_per_sec, ctx.t
                        );
                        fired +=
                            self.try_fire(idx, subject, ctx.t, ctx.ess_per_sec, floor, msg);
                    }
                }
                AlertRule::SplitRhatAbove {
                    threshold, warmup_iters, min_samples, ..
                } => {
                    if let Some(rhat) = ctx.split_rhat {
                        if ctx.t >= warmup_iters
                            && ctx.samples >= min_samples
                            && rhat.is_finite()
                            && rhat > threshold
                        {
                            let msg = format!(
                                "split-Rhat {rhat:.4} above {threshold:.4} at t={}",
                                ctx.t
                            );
                            fired +=
                                self.try_fire(idx, subject, ctx.t, rhat, threshold, msg);
                        }
                    }
                }
                _ => {}
            }
        }
        fired
    }

    /// Evaluate the node rules against one node execution / message
    /// update. Returns the number of events fired (post-cooldown).
    pub fn eval_node(&mut self, ctx: &NodeCtx) -> usize {
        let subject = Subject::Node(ctx.node);
        let mut fired = 0;
        for idx in 0..self.rules.len() {
            let rule = self.rules[idx].clone();
            match rule {
                AlertRule::StallTimeRatioAbove { ratio, min_execs, .. } => {
                    if ctx.execs >= min_execs
                        && ctx.stall_ratio.is_finite()
                        && ctx.stall_ratio > ratio
                    {
                        let msg = format!(
                            "node {} stalled {:.1}% of virtual time (> {:.1}%)",
                            ctx.node,
                            100.0 * ctx.stall_ratio,
                            100.0 * ratio
                        );
                        fired +=
                            self.try_fire(idx, subject, ctx.t, ctx.stall_ratio, ratio, msg);
                    }
                }
                AlertRule::StalenessPinned { k, .. } => {
                    if ctx.tau > 0 && ctx.consecutive_at_tau >= k {
                        let msg = format!(
                            "node {} pinned at staleness tau={} for {} consecutive \
                             executions",
                            ctx.node, ctx.tau, ctx.consecutive_at_tau
                        );
                        fired += self.try_fire(
                            idx,
                            subject,
                            ctx.t,
                            ctx.consecutive_at_tau as f64,
                            k as f64,
                            msg,
                        );
                    }
                }
                AlertRule::MsgsDroppedRatioAbove { ratio, min_sent, .. } => {
                    if ctx.msgs_sent >= min_sent {
                        let drop_ratio = ctx.msgs_dropped as f64 / ctx.msgs_sent as f64;
                        if drop_ratio > ratio {
                            let msg = format!(
                                "node {} dropped {}/{} messages ({:.1}% > {:.1}%)",
                                ctx.node,
                                ctx.msgs_dropped,
                                ctx.msgs_sent,
                                100.0 * drop_ratio,
                                100.0 * ratio
                            );
                            fired +=
                                self.try_fire(idx, subject, ctx.t, drop_ratio, ratio, msg);
                        }
                    }
                }
                _ => {}
            }
        }
        fired
    }

    /// Fire unless the (rule, subject) pair is still cooling down.
    /// Returns 1 if an event was recorded.
    fn try_fire(
        &mut self,
        rule_idx: usize,
        subject: Subject,
        t: u64,
        value: f64,
        threshold: f64,
        message: String,
    ) -> usize {
        let key = (rule_idx, subject);
        let cooldown = self.rules[rule_idx].cooldown();
        if let Some(&last) = self.last_fire.get(&key) {
            if t < last.saturating_add(cooldown) {
                *self.suppressed.entry(key).or_insert(0) += 1;
                return 0;
            }
        }
        let suppressed_since_last = self.suppressed.remove(&key).unwrap_or(0);
        self.last_fire.insert(key, t);
        self.events.push(HealthEvent {
            severity: self.rules[rule_idx].severity(),
            rule: self.rules[rule_idx].name(),
            subject: subject.label(),
            t,
            value,
            threshold,
            message,
            suppressed_since_last,
        });
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nan_ctx(t: u64) -> SampleCtx {
        SampleCtx {
            chain: 0,
            t,
            value: f64::NAN,
            samples: t,
            ess_per_sec: f64::NAN,
            split_rhat: None,
        }
    }

    #[test]
    fn nan_rule_fires_once_per_cooldown_window() {
        let mut eng = AlertEngine::new(vec![AlertRule::NonFiniteValue { cooldown: 100 }]);
        for t in 1..=300 {
            eng.eval_sample(&nan_ctx(t));
        }
        let events = eng.events();
        assert_eq!(events.len(), 3, "fired at t=1, 101, 201");
        assert_eq!(events[0].t, 1);
        assert_eq!(events[1].t, 101);
        assert_eq!(events[2].t, 201);
        assert_eq!(events[0].suppressed_since_last, 0);
        assert_eq!(events[1].suppressed_since_last, 99);
        assert_eq!(events[2].suppressed_since_last, 99);
        assert!(events.iter().all(|e| e.rule == "non_finite_value"));
        assert!(events.iter().all(|e| e.severity == Severity::Critical));
    }

    #[test]
    fn cooldown_is_per_subject() {
        let mut eng = AlertEngine::new(vec![AlertRule::NonFiniteValue { cooldown: 100 }]);
        for chain in 0..3 {
            let mut ctx = nan_ctx(5);
            ctx.chain = chain;
            eng.eval_sample(&ctx);
        }
        assert_eq!(eng.events().len(), 3, "one event per chain, no cross-talk");
    }

    #[test]
    fn finite_values_never_fire() {
        let mut eng = AlertEngine::with_default_rules();
        for t in 1..=200 {
            let mut ctx = nan_ctx(t);
            ctx.value = -1.5;
            eng.eval_sample(&ctx);
        }
        assert!(eng.events().is_empty());
    }

    #[test]
    fn staleness_pinned_requires_positive_tau() {
        let mut eng = AlertEngine::new(vec![AlertRule::StalenessPinned {
            k: 4,
            cooldown: 10,
        }]);
        let mut ctx = NodeCtx {
            node: 1,
            t: 20,
            execs: 20,
            staleness: 0,
            tau: 0,
            consecutive_at_tau: 20,
            stall_ratio: 0.0,
            msgs_sent: 0,
            msgs_dropped: 0,
        };
        eng.eval_node(&ctx);
        assert!(eng.events().is_empty(), "tau=0 means the bound is vacuous");
        ctx.tau = 4;
        ctx.staleness = 4;
        eng.eval_node(&ctx);
        assert_eq!(eng.events().len(), 1);
        assert_eq!(eng.events()[0].rule, "staleness_pinned");
        assert_eq!(eng.events()[0].subject, "node1");
    }

    #[test]
    fn event_json_maps_non_finite_to_null() {
        let ev = HealthEvent {
            severity: Severity::Critical,
            rule: "non_finite_value",
            subject: "chain0".to_string(),
            t: 7,
            value: f64::NAN,
            threshold: 0.0,
            message: "monitored value is NaN at t=7".to_string(),
            suppressed_since_last: 0,
        };
        let j = ev.to_json();
        assert!(matches!(j.field("value").unwrap(), Json::Null));
        assert_eq!(j.field("t").unwrap().as_u64().unwrap(), 7);
        let line = j.to_string_compact();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(
            parsed.field("rule").unwrap().as_str().unwrap(),
            "non_finite_value"
        );
    }
}
