//! Tiny blocking OpenMetrics scrape endpoint (std `TcpListener`, no
//! dependencies, one request per connection).
//!
//! The server renders [`super::openmetrics::render_openmetrics`] fresh
//! on every request, so a scraper always sees the current counters and
//! health gauges. It runs on one named thread and is torn down on
//! [`Drop`] by a self-connect that unblocks `accept`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::{Error, Result};

/// A running scrape endpoint; dropping it stops the serving thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, or port `0` for ephemeral)
    /// and serve the exposition until dropped.
    pub fn spawn(addr: &str) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Config(format!("PALLAS_METRICS_ADDR {addr:?}: {e}")))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_thread = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("pallas-metrics".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop_thread.load(Ordering::Acquire) {
                        break;
                    }
                    if let Ok(stream) = stream {
                        // Scrape failures are the scraper's problem;
                        // never let them take the sampler down.
                        let _ = serve_one(stream);
                    }
                }
            })?;
        crate::log_info!("metrics endpoint listening on http://{addr}/metrics");
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // accept() blocks; a throwaway connection wakes it so the
        // thread observes the stop flag and exits.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_millis(200));
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// Answer one HTTP request with the current exposition and close.
fn serve_one(stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream);
    // Consume the request head (request line + headers) up to the
    // blank line; the body (if any) is ignored.
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 || line == "\r\n" || line == "\n" {
            break;
        }
    }
    let body = super::openmetrics::render_openmetrics();
    let mut stream = reader.into_inner();
    let head = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: application/openmetrics-text; version=1.0.0; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}
