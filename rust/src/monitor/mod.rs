//! Online sampler-health monitor: streaming convergence diagnostics,
//! anomaly alerts, and OpenMetrics exposition.
//!
//! # DESIGN
//!
//! The obs layer (ISSUE 9) records *what the code did* — spans,
//! counters, traces. This module layers *is the sampler healthy?* on
//! top of it, online, while the run is still burning budget:
//!
//! ```text
//!   run_sampler ──monitored value──▶ observe_sample ─┐
//!   multichain  ──(with_chain idx)──▶      "         │   ┌───────────┐
//!   async_sim   ──exec/stall/msgs──▶ observe_node_*  ├──▶│ Monitor   │
//!                                                    │   │ (mutexed) │
//!                                                    │   └─────┬─────┘
//!        streaming estimators: Welford, OnlineRhat,  │         │
//!        RingWindow → windowed IAT → ESS/sec,        │         ▼
//!        ReservoirQuantiles (O(1)/bounded memory)    │   AlertEngine
//!                                                    │   (rules + cooldown)
//!                                                    │         │
//!              health.jsonl ◀── structured events ◀──┘         │
//!              metrics.prom ◀── OpenMetrics render ◀── gauges ◀┘
//!              (+ optional PALLAS_METRICS_ADDR scrape endpoint)
//! ```
//!
//! ## Contracts
//!
//! * **Never perturbs the chain.** The monitor only observes values the
//!   samplers already compute; it draws randomness from its own derived
//!   RNG stream and never touches sampler RNGs, schedules, or state. At
//!   `PALLAS_OBS=off` every entry point is an early-return, so chain
//!   output is bitwise identical with the monitor compiled in or out.
//! * **Off the hot path.** Feeds happen at monitor cadence
//!   (`RunConfig::monitor_every`) and at async-sim virtual events, never
//!   inside `Psgld::step` — the zero-alloc guarantee of the step hot
//!   path (`tests/alloc_free.rs`) is untouched.
//! * **Bounded memory.** Welford is O(1); windows and reservoirs are
//!   fixed-capacity; the alert engine holds one cooldown slot per
//!   (rule, subject) pair plus the fired events.
//! * **Quiet by default.** [`AlertRule::default_set`] only contains
//!   rules that cannot fire on a healthy run (NaN values, pathological
//!   stall/staleness/drop regimes). Trend rules (ESS floor, split-R̂
//!   threshold) are opted in per run via [`set_rules`].
//!
//! ## Consumers
//!
//! * `main.rs` writes `metrics.prom`, `health.jsonl`, and
//!   `health_summary.json` next to the other obs artifacts, and serves
//!   the exposition live when `PALLAS_METRICS_ADDR` (or
//!   `--metrics-addr`) is set.
//! * `check-regression` (CLI) compares fresh `BENCH_*.json` /
//!   `health_summary.json` against committed baselines — see
//!   [`regression`].

pub mod alert;
pub mod openmetrics;
pub mod regression;
pub mod serve;
pub mod streaming;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::obs::logger::{log_event, LogLevel};
use crate::obs::{self, ObsLevel};
use crate::util::Json;
use crate::Result;

pub use alert::{AlertEngine, AlertRule, HealthEvent, NodeCtx, SampleCtx, Severity};
pub use openmetrics::{lint_openmetrics, render_openmetrics};
pub use regression::{check_regression, RegressionFinding, RegressionReport};
pub use serve::MetricsServer;
pub use streaming::{
    split_rhat_window, windowed_iat, OnlineRhat, ReservoirQuantiles, RingWindow, Welford,
};

/// Monitored-value window size per chain (IAT / split-R̂ horizon).
const WINDOW_CAP: usize = 1024;
/// Reservoir size for the per-chain value quantiles.
const RESERVOIR_CAP: usize = 512;
/// Recompute the windowed IAT every sample below this window size,
/// then only every [`ESS_REFRESH_EVERY`] samples (the estimator is
/// O(window²); the gauge does not need per-sample freshness).
const ESS_CHEAP_BELOW: usize = 256;
const ESS_REFRESH_EVERY: u64 = 16;

/// The monitor piggybacks on the obs level: active at `counters` and
/// `full`, a no-op at `off`.
pub fn enabled() -> bool {
    obs::level() >= ObsLevel::Counters
}

thread_local! {
    static CHAIN: Cell<usize> = Cell::new(0);
}

/// Run `f` with samples attributed to `chain` (used by the multi-chain
/// driver so per-chain streams stay separate).
pub fn with_chain<R>(chain: usize, f: impl FnOnce() -> R) -> R {
    let prev = CHAIN.with(|c| c.replace(chain));
    let out = f();
    CHAIN.with(|c| c.set(prev));
    out
}

/// Per-chain streaming health state.
struct ChainHealth {
    samples: u64,
    non_finite: u64,
    welford: Welford,
    window: RingWindow,
    /// Cumulative sampling-seconds aligned with `window` entries.
    sec_window: RingWindow,
    quantiles: ReservoirQuantiles,
    /// Latest windowed ESS/sec (NaN until computable).
    ess_per_sec: f64,
}

impl ChainHealth {
    fn new(chain: usize) -> Self {
        ChainHealth {
            samples: 0,
            non_finite: 0,
            welford: Welford::new(),
            window: RingWindow::new(WINDOW_CAP),
            sec_window: RingWindow::new(WINDOW_CAP),
            quantiles: ReservoirQuantiles::new(RESERVOIR_CAP, chain as u64),
            ess_per_sec: f64::NAN,
        }
    }
}

/// Per-node streaming health state (async executor feed).
#[derive(Default)]
struct NodeHealth {
    execs: u64,
    stalls: u64,
    busy_s: f64,
    stall_s: f64,
    staleness_sum: u64,
    max_staleness: u64,
    consecutive_at_tau: u64,
    tau: u64,
    last_staleness: u64,
    msgs_sent: u64,
    msgs_dropped: u64,
}

impl NodeHealth {
    fn stall_ratio(&self) -> f64 {
        let total = self.busy_s + self.stall_s;
        if total > 0.0 {
            self.stall_s / total
        } else {
            f64::NAN
        }
    }

    fn ctx(&self, node: usize, t: u64) -> NodeCtx {
        NodeCtx {
            node,
            t,
            execs: self.execs,
            staleness: self.last_staleness,
            tau: self.tau,
            consecutive_at_tau: self.consecutive_at_tau,
            stall_ratio: self.stall_ratio(),
            msgs_sent: self.msgs_sent,
            msgs_dropped: self.msgs_dropped,
        }
    }
}

struct MonitorState {
    chains: BTreeMap<usize, ChainHealth>,
    nodes: BTreeMap<usize, NodeHealth>,
    engine: AlertEngine,
    context: String,
    /// Events already forwarded to the obs logger.
    logged: usize,
}

impl MonitorState {
    fn new() -> Self {
        MonitorState {
            chains: BTreeMap::new(),
            nodes: BTreeMap::new(),
            engine: AlertEngine::with_default_rules(),
            context: String::new(),
            logged: 0,
        }
    }

    fn observe_sample(&mut self, chain: usize, t: u64, seconds: f64, value: f64) {
        let ch = self.chains.entry(chain).or_insert_with(|| ChainHealth::new(chain));
        ch.samples += 1;
        if value.is_finite() {
            ch.welford.push(value);
            ch.window.push(value);
            ch.sec_window.push(seconds);
            ch.quantiles.push(value);
            let n = ch.window.len();
            if n >= 16 && (n < ESS_CHEAP_BELOW || ch.samples % ESS_REFRESH_EVERY == 0) {
                let span = seconds - ch.sec_window.front().unwrap_or(seconds);
                if span > 0.0 {
                    let iat = windowed_iat(&ch.window);
                    ch.ess_per_sec = n as f64 / iat / span;
                }
            }
        } else {
            ch.non_finite += 1;
        }
        let samples = ch.samples;
        let ess_per_sec = ch.ess_per_sec;
        let split_rhat = self.split_rhat();
        let ctx = SampleCtx { chain, t, value, samples, ess_per_sec, split_rhat };
        self.engine.eval_sample(&ctx);
        self.flush_log();
    }

    /// Across-chain split-R̂ over the recent windows when at least two
    /// chains have data, else the single stream's half-vs-half R̂.
    fn split_rhat(&self) -> Option<f64> {
        let ready: Vec<&ChainHealth> =
            self.chains.values().filter(|c| c.window.len() >= 4).collect();
        match ready.len() {
            0 => None,
            1 => split_rhat_window(&ready[0].window),
            _ => {
                let windows: Vec<Vec<f64>> =
                    ready.iter().map(|c| c.window.to_vec()).collect();
                Some(crate::metrics::diagnostics::gelman_rubin(&windows))
            }
        }
    }

    fn observe_node_exec(
        &mut self,
        node: usize,
        t: u64,
        staleness: u64,
        tau: u64,
        busy_s: f64,
    ) {
        let nh = self.nodes.entry(node).or_default();
        nh.execs += 1;
        nh.busy_s += busy_s;
        nh.staleness_sum += staleness;
        nh.max_staleness = nh.max_staleness.max(staleness);
        nh.tau = tau;
        nh.last_staleness = staleness;
        nh.consecutive_at_tau =
            if tau > 0 && staleness == tau { nh.consecutive_at_tau + 1 } else { 0 };
        let ctx = nh.ctx(node, t);
        self.engine.eval_node(&ctx);
        self.flush_log();
    }

    fn observe_node_stall(&mut self, node: usize, stall_s: f64) {
        let nh = self.nodes.entry(node).or_default();
        nh.stalls += 1;
        nh.stall_s += stall_s;
        // No rule evaluation here: a resolved stall is always followed
        // by an execution of the same node, which evaluates with the
        // updated ratio.
    }

    fn observe_node_msgs(&mut self, node: usize, t: u64, sent: u64, dropped: u64) {
        let nh = self.nodes.entry(node).or_default();
        nh.msgs_sent += sent;
        nh.msgs_dropped += dropped;
        if dropped > 0 {
            // Evaluate on drops so a crashed node's spike still alerts
            // even if it never executes again.
            let ctx = nh.ctx(node, t);
            self.engine.eval_node(&ctx);
            self.flush_log();
        }
    }

    /// Forward newly fired events to the obs logger as structured
    /// single-line JSON records.
    fn flush_log(&mut self) {
        let events = self.engine.events();
        while self.logged < events.len() {
            let ev = &events[self.logged];
            let lvl = match ev.severity {
                Severity::Critical => LogLevel::Error,
                Severity::Warn => LogLevel::Warn,
                Severity::Info => LogLevel::Info,
            };
            log_event(lvl, &ev.to_json());
            self.logged += 1;
        }
    }
}

fn lock() -> MutexGuard<'static, MonitorState> {
    static MONITOR: OnceLock<Mutex<MonitorState>> = OnceLock::new();
    MONITOR
        .get_or_init(|| Mutex::new(MonitorState::new()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Feed one monitored sample (loglik / RMSE at a monitor tick).
/// `seconds` is the cumulative sampling time at the tick. Attribution
/// to a chain comes from [`with_chain`]; the default is chain 0.
pub fn observe_sample(t: u64, seconds: f64, value: f64) {
    if !enabled() {
        return;
    }
    let chain = CHAIN.with(|c| c.get());
    lock().observe_sample(chain, t, seconds, value);
}

/// Feed one completed node execution from the async executor.
pub fn observe_node_exec(node: usize, t: u64, staleness: u64, tau: u64, busy_s: f64) {
    if !enabled() {
        return;
    }
    lock().observe_node_exec(node, t, staleness, tau, busy_s);
}

/// Feed one resolved stall interval (virtual seconds) for `node`.
pub fn observe_node_stall(node: usize, stall_s: f64) {
    if !enabled() {
        return;
    }
    lock().observe_node_stall(node, stall_s);
}

/// Feed message-counter deltas for `node` (`t` is the producing
/// iteration, used as the cooldown clock for drop alerts).
pub fn observe_node_msgs(node: usize, t: u64, sent: u64, dropped: u64) {
    if !enabled() {
        return;
    }
    lock().observe_node_msgs(node, t, sent, dropped);
}

/// Label the current run in the health summary (e.g. sampler name).
pub fn set_context(label: &str) {
    if !enabled() {
        return;
    }
    lock().context = label.to_string();
}

/// Replace the active alert rules (clears cooldown state, keeps the
/// fired-event history).
pub fn set_rules(rules: Vec<AlertRule>) {
    lock().engine.set_rules(rules);
}

/// Drop all streaming state, events, and cooldowns; restore the
/// default rule set.
pub fn reset() {
    *lock() = MonitorState::new();
}

/// Snapshot of the fired health events.
pub fn events() -> Vec<HealthEvent> {
    lock().engine.events().to_vec()
}

/// Total fired alerts so far.
pub fn alerts_total() -> usize {
    lock().engine.events().len()
}

/// Point-in-time gauges for one chain.
#[derive(Clone, Debug)]
pub struct ChainGauges {
    pub chain: usize,
    pub samples: u64,
    pub non_finite: u64,
    pub mean: f64,
    pub sd: f64,
    pub ess_per_sec: f64,
    pub q05: f64,
    pub q50: f64,
    pub q95: f64,
}

/// Point-in-time gauges for one async node.
#[derive(Clone, Debug)]
pub struct NodeGauges {
    pub node: usize,
    pub execs: u64,
    pub stalls: u64,
    pub stall_ratio: f64,
    pub mean_staleness: f64,
    pub max_staleness: u64,
    pub consecutive_at_tau: u64,
    pub msgs_sent: u64,
    pub msgs_dropped: u64,
}

/// Everything the exposition / summary needs, copied out of the lock.
#[derive(Clone, Debug)]
pub struct HealthSnapshot {
    pub context: String,
    pub chains: Vec<ChainGauges>,
    pub nodes: Vec<NodeGauges>,
    pub split_rhat: Option<f64>,
    /// Sum of the per-chain windowed ESS/sec (None until any chain has
    /// a finite estimate).
    pub ess_per_sec: Option<f64>,
    pub alerts_info: usize,
    pub alerts_warn: usize,
    pub alerts_critical: usize,
}

/// Copy the current health gauges out of the monitor.
pub fn health_snapshot() -> HealthSnapshot {
    let m = lock();
    let chains: Vec<ChainGauges> = m
        .chains
        .iter()
        .map(|(&chain, c)| ChainGauges {
            chain,
            samples: c.samples,
            non_finite: c.non_finite,
            mean: c.welford.mean(),
            sd: c.welford.sd(),
            ess_per_sec: c.ess_per_sec,
            q05: c.quantiles.quantile(0.05),
            q50: c.quantiles.quantile(0.5),
            q95: c.quantiles.quantile(0.95),
        })
        .collect();
    let nodes: Vec<NodeGauges> = m
        .nodes
        .iter()
        .map(|(&node, n)| NodeGauges {
            node,
            execs: n.execs,
            stalls: n.stalls,
            stall_ratio: n.stall_ratio(),
            mean_staleness: if n.execs > 0 {
                n.staleness_sum as f64 / n.execs as f64
            } else {
                f64::NAN
            },
            max_staleness: n.max_staleness,
            consecutive_at_tau: n.consecutive_at_tau,
            msgs_sent: n.msgs_sent,
            msgs_dropped: n.msgs_dropped,
        })
        .collect();
    let finite: Vec<f64> =
        chains.iter().map(|c| c.ess_per_sec).filter(|e| e.is_finite()).collect();
    HealthSnapshot {
        context: m.context.clone(),
        split_rhat: m.split_rhat(),
        ess_per_sec: if finite.is_empty() { None } else { Some(finite.iter().sum()) },
        alerts_info: m.engine.count_by_severity(Severity::Info),
        alerts_warn: m.engine.count_by_severity(Severity::Warn),
        alerts_critical: m.engine.count_by_severity(Severity::Critical),
        chains,
        nodes,
    }
}

fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Machine-readable health summary (schema `psgld-health-summary/1`).
/// The top-level `alerts_total` is what CI greps for.
pub fn health_summary_json() -> Json {
    let h = health_snapshot();
    let chains: Vec<Json> = h
        .chains
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("chain", Json::num(c.chain as f64)),
                ("samples", Json::num(c.samples as f64)),
                ("non_finite", Json::num(c.non_finite as f64)),
                ("mean", jnum(c.mean)),
                ("sd", jnum(c.sd)),
                ("ess_per_sec", jnum(c.ess_per_sec)),
                ("q05", jnum(c.q05)),
                ("q50", jnum(c.q50)),
                ("q95", jnum(c.q95)),
            ])
        })
        .collect();
    let nodes: Vec<Json> = h
        .nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("node", Json::num(n.node as f64)),
                ("execs", Json::num(n.execs as f64)),
                ("stalls", Json::num(n.stalls as f64)),
                ("stall_ratio", jnum(n.stall_ratio)),
                ("mean_staleness", jnum(n.mean_staleness)),
                ("max_staleness", Json::num(n.max_staleness as f64)),
                ("consecutive_at_tau", Json::num(n.consecutive_at_tau as f64)),
                ("msgs_sent", Json::num(n.msgs_sent as f64)),
                ("msgs_dropped", Json::num(n.msgs_dropped as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("psgld-health-summary/1".to_string())),
        ("context", Json::Str(h.context.clone())),
        (
            "alerts_total",
            Json::num((h.alerts_info + h.alerts_warn + h.alerts_critical) as f64),
        ),
        (
            "alerts",
            Json::obj(vec![
                ("critical", Json::num(h.alerts_critical as f64)),
                ("info", Json::num(h.alerts_info as f64)),
                ("warn", Json::num(h.alerts_warn as f64)),
            ]),
        ),
        (
            "gauges",
            Json::obj(vec![
                ("chains", Json::num(h.chains.len() as f64)),
                ("ess_per_sec", h.ess_per_sec.map_or(Json::Null, jnum)),
                ("nodes", Json::num(h.nodes.len() as f64)),
                ("split_rhat", h.split_rhat.map_or(Json::Null, jnum)),
            ]),
        ),
        ("chains", Json::Arr(chains)),
        ("nodes", Json::Arr(nodes)),
    ])
}

/// Write every fired health event as one JSON line; an empty file
/// means a clean run. Returns the number of events written.
pub fn write_health_jsonl(path: &Path) -> Result<usize> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let evs = events();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for ev in &evs {
        writeln!(f, "{}", ev.to_json().to_string_compact())?;
    }
    f.flush()?;
    Ok(evs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_feed_is_a_noop() {
        let _g = crate::obs::test_guard();
        crate::obs::set_level_override(Some(ObsLevel::Off));
        reset();
        observe_sample(1, 0.1, f64::NAN);
        observe_node_exec(0, 1, 3, 2, 0.5);
        let h = health_snapshot();
        assert!(h.chains.is_empty());
        assert!(h.nodes.is_empty());
        assert_eq!(alerts_total(), 0);
        crate::obs::set_level_override(None);
    }

    #[test]
    fn chain_attribution_and_summary() {
        let _g = crate::obs::test_guard();
        crate::obs::set_level_override(Some(ObsLevel::Counters));
        reset();
        for t in 1..=50u64 {
            with_chain(0, || observe_sample(t, t as f64 * 0.1, (t % 7) as f64));
            with_chain(1, || observe_sample(t, t as f64 * 0.1, (t % 7) as f64 + 0.1));
        }
        let h = health_snapshot();
        assert_eq!(h.chains.len(), 2);
        assert_eq!(h.chains[0].samples, 50);
        assert!(h.split_rhat.is_some(), "two chains with data give a split-Rhat");
        let summary = health_summary_json();
        assert_eq!(summary.field("alerts_total").unwrap().as_u64().unwrap(), 0);
        assert_eq!(
            summary.field("gauges").unwrap().field("chains").unwrap().as_u64().unwrap(),
            2
        );
        reset();
        crate::obs::set_level_override(None);
    }

    #[test]
    fn nan_sample_fires_critical_alert_and_jsonl_round_trips() {
        let _g = crate::obs::test_guard();
        crate::obs::set_level_override(Some(ObsLevel::Counters));
        reset();
        observe_sample(1, 0.0, 1.0);
        observe_sample(2, 0.1, f64::INFINITY);
        let evs = events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].rule, "non_finite_value");
        assert_eq!(evs[0].severity, Severity::Critical);
        let path = std::env::temp_dir().join("psgld_monitor_health.jsonl");
        let n = write_health_jsonl(&path).unwrap();
        assert_eq!(n, 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.field("rule").unwrap().as_str().unwrap(), "non_finite_value");
        let _ = std::fs::remove_file(&path);
        reset();
        crate::obs::set_level_override(None);
    }

    #[test]
    fn node_feed_tracks_stall_ratio_and_staleness() {
        let _g = crate::obs::test_guard();
        crate::obs::set_level_override(Some(ObsLevel::Counters));
        reset();
        set_rules(vec![AlertRule::StalenessPinned { k: 4, cooldown: 1000 }]);
        for t in 1..=10u64 {
            observe_node_exec(2, t, 3, 3, 0.5);
            observe_node_stall(2, 0.25);
        }
        let h = health_snapshot();
        assert_eq!(h.nodes.len(), 1);
        let n = &h.nodes[0];
        assert_eq!(n.execs, 10);
        assert!((n.stall_ratio - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(n.max_staleness, 3);
        assert_eq!(n.consecutive_at_tau, 10);
        let evs = events();
        assert_eq!(evs.len(), 1, "pinned-staleness alert fires once under cooldown");
        assert_eq!(evs[0].rule, "staleness_pinned");
        reset();
        crate::obs::set_level_override(None);
    }
}
