//! Crate-wide error type.

use std::fmt;

/// Errors produced by the PSGLD library.
#[derive(Debug)]
pub enum Error {
    /// Configuration or argument validation failure.
    Config(String),
    /// Shape mismatch between operands.
    Shape(String),
    /// Artifact manifest / runtime errors (missing executable, ...).
    Runtime(String),
    /// Underlying XLA/PJRT error.
    #[cfg(feature = "xla")]
    Xla(xla::Error),
    /// I/O error (artifact files, CSV output, datasets).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            #[cfg(feature = "xla")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
/// Construct an [`Error::Config`] with format syntax.
macro_rules! config_err {
    ($($arg:tt)*) => { $crate::Error::Config(format!($($arg)*)) };
}

#[macro_export]
/// Construct an [`Error::Shape`] with format syntax.
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::Error::Shape(format!($($arg)*)) };
}
