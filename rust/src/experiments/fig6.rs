//! Fig. 6 — scalability of distributed PSGLD on the simulated cluster
//! (DESIGN.md §3 substitution; cost model in [`crate::cluster`]).
//!
//! (a) strong scaling: MovieLens-10M workload, 100 samples, nodes
//!     B ∈ {5, ..., 120}: runtime falls ~quadratically until the ring
//!     communication dominates (paper: knee between B = 90 and 120);
//! (b) weak scaling: data ×4 and nodes ×2 per step up to
//!     683 584 × 4 580 288 (640M nnz) on 120 nodes, T = 10: runtime
//!     stays nearly flat.

use std::io::Write;

use crate::cluster::{
    dsgld_distributed_timing, psgld_distributed_timing, ComputeModel, NetworkModel,
    TimingWorkload,
};
use crate::experiments::common::{fmt_s, print_table, ExpOptions};
use crate::Result;

pub struct ScalingRow {
    pub b: usize,
    pub workload_nnz: u64,
    pub total_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
}

fn write_csv(path: &std::path::Path, rows: &[ScalingRow]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "nodes,nnz,total_s,compute_s,comm_s")?;
    for r in rows {
        writeln!(
            f,
            "{},{},{},{},{}",
            r.b, r.workload_nnz, r.total_s, r.compute_s, r.comm_s
        )?;
    }
    crate::log_info!("  wrote {}", path.display());
    Ok(())
}

/// Fig. 6(a): fixed data, growing node count.
pub fn fig6a(opts: &ExpOptions) -> Result<Vec<ScalingRow>> {
    let wl = TimingWorkload::ml10m(50);
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let iters = opts.t(100, 100);
    let rows: Vec<ScalingRow> = [5usize, 15, 30, 45, 60, 75, 90, 105, 120]
        .iter()
        .map(|&b| {
            let rep = psgld_distributed_timing(&wl, b, iters, &net, &compute);
            ScalingRow {
                b,
                workload_nnz: wl.nnz,
                total_s: rep.virtual_seconds,
                compute_s: rep.compute_seconds,
                comm_s: rep.comm_seconds,
            }
        })
        .collect();
    write_csv(&opts.csv_path("fig6a_strong_scaling.csv"), &rows)?;

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.b.to_string(),
                fmt_s(r.total_s),
                fmt_s(r.compute_s),
                fmt_s(r.comm_s),
            ]
        })
        .collect();
    print_table(
        &format!("Fig 6(a) strong scaling ({} samples, ML-10M workload, simulated cluster)", iters),
        &["nodes", "total", "compute", "comm"],
        &table,
    );

    // the knee: where the curve stops improving
    let knee = rows
        .windows(2)
        .find(|w| w[1].total_s > w[0].total_s)
        .map(|w| w[1].b);
    crate::log_info!(
        "  knee (communication dominates) at B = {:?} — paper observed it at B = 120",
        knee
    );
    Ok(rows)
}

/// Fig. 6(b): data and nodes grown together.
pub fn fig6b(opts: &ExpOptions) -> Result<Vec<ScalingRow>> {
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let iters = opts.t(10, 10);
    let base = TimingWorkload::ml10m(50);
    let rows: Vec<ScalingRow> = (0..4u32)
        .map(|s| {
            let wl = base.doubled(s);
            let b = 15usize << s;
            let rep = psgld_distributed_timing(&wl, b, iters, &net, &compute);
            ScalingRow {
                b,
                workload_nnz: wl.nnz,
                total_s: rep.virtual_seconds,
                compute_s: rep.compute_seconds,
                comm_s: rep.comm_seconds,
            }
        })
        .collect();
    write_csv(&opts.csv_path("fig6b_weak_scaling.csv"), &rows)?;

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.b.to_string(),
                format!("{:.0}M", r.workload_nnz as f64 / 1e6),
                fmt_s(r.total_s),
                format!("{:.2}x", r.total_s / rows[0].total_s),
            ]
        })
        .collect();
    print_table(
        &format!("Fig 6(b) weak scaling (T = {iters}, data x4 & nodes x2 per step)"),
        &["nodes", "nnz", "total", "vs 15 nodes"],
        &table,
    );
    crate::log_info!(
        "  paper's claim: 64x data on 8x nodes at nearly constant time; \
         measured growth {:.0}%",
        (rows.last().unwrap().total_s / rows[0].total_s - 1.0) * 100.0
    );
    Ok(rows)
}

/// §1 communication-cost comparison: PSGLD vs DSGLD bytes/time on the
/// wire for the same workload (supports the paper's motivation).
pub fn comm_comparison(opts: &ExpOptions) -> Result<()> {
    let wl = TimingWorkload::ml10m(50);
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let iters = opts.t(100, 1000);
    let p = psgld_distributed_timing(&wl, 15, iters, &net, &compute);
    // DSGLD with a comparable per-iteration workload and sync every 2
    let omega = (wl.nnz as usize / 15 / 100).max(1);
    let d = dsgld_distributed_timing(&wl, 15, omega, 2, iters, &net, &compute);
    print_table(
        "DSGLD vs PSGLD communication (simulated, 15 nodes)",
        &["method", "compute", "comm", "total"],
        &[
            vec![
                "psgld".into(),
                fmt_s(p.compute_seconds),
                fmt_s(p.comm_seconds),
                fmt_s(p.virtual_seconds),
            ],
            vec![
                "dsgld".into(),
                fmt_s(d.compute_seconds),
                fmt_s(d.comm_seconds),
                fmt_s(d.virtual_seconds),
            ],
        ],
    );
    crate::log_info!(
        "  comm ratio dsgld/psgld = {:.0}x (paper §1: PSGLD communicates only \
         small parts of H)",
        d.comm_seconds / p.comm_seconds.max(1e-12)
    );
    Ok(())
}
