//! Shared experiment plumbing: options, output handling and the table
//! printer used by every figure harness.

use std::path::{Path, PathBuf};

use crate::metrics::Trace;
use crate::Result;

/// Options shared by every experiment harness.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Output directory for CSVs.
    pub outdir: PathBuf,
    /// Artifacts directory (HLO executables).
    pub artifacts: PathBuf,
    /// Master seed.
    pub seed: u64,
    /// Run at the paper's full scale (hours) instead of the scaled-down
    /// default (minutes).
    pub full: bool,
    /// Override for the iteration count (None = harness default).
    pub iters: Option<u64>,
    /// Include the Gibbs comparator at large sizes (slow).
    pub gibbs: bool,
    /// Write a Chrome/Perfetto trace-event JSON here after the run
    /// (implies `PALLAS_OBS=full` unless the env var says otherwise).
    pub trace_out: Option<PathBuf>,
    /// Serve the OpenMetrics exposition at this address for the run's
    /// duration (implies `PALLAS_OBS=counters` unless the env var says
    /// otherwise). Resolved from `--metrics-addr` / `PALLAS_METRICS_ADDR`.
    pub metrics_addr: Option<String>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            outdir: PathBuf::from("results"),
            artifacts: PathBuf::from("artifacts"),
            seed: 2015,
            full: false,
            iters: None,
            gibbs: true,
            trace_out: None,
            metrics_addr: None,
        }
    }
}

impl ExpOptions {
    /// Iteration count: explicit override, else `full_iters` when
    /// `--full`, else the scaled default.
    pub fn t(&self, default_iters: u64, full_iters: u64) -> u64 {
        self.iters.unwrap_or(if self.full { full_iters } else { default_iters })
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.outdir.join(name)
    }

    /// True when the AOT artifacts are present (HLO-backed runs).
    pub fn has_artifacts(&self) -> bool {
        self.artifacts.join("manifest.json").exists()
    }
}

/// Print an aligned two-column-plus table, paper-style.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    crate::log_info!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        crate::log_info!("  {}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// Write a set of traces as one CSV and report where.
pub fn save_traces(path: &Path, traces: &[&Trace]) -> Result<()> {
    crate::metrics::trace::write_csv_multi(traces, path)?;
    crate::log_info!("  wrote {}", path.display());
    Ok(())
}

/// Seconds formatted compactly.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_selection() {
        let mut o = ExpOptions::default();
        assert_eq!(o.t(100, 10_000), 100);
        o.full = true;
        assert_eq!(o.t(100, 10_000), 10_000);
        o.iters = Some(42);
        assert_eq!(o.t(100, 10_000), 42);
    }

    #[test]
    fn fmt_s_ranges() {
        assert!(fmt_s(5e-4).ends_with("us"));
        assert!(fmt_s(0.02).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(300.0).ends_with("min"));
    }
}
