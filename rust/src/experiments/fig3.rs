//! Fig. 3 — audio (piano spectrogram) decomposition: PSGLD vs LD
//! dictionaries (Monte Carlo averages over post-burn-in samples) plus
//! the running-time comparison (paper: PSGLD 3.5 s, LD 81 s, Gibbs
//! 533 s on the same 256×256, K=8 problem).

use crate::config::{RunConfig, StepSchedule};
use crate::data::audio;
use crate::experiments::common::{fmt_s, print_table, save_traces, ExpOptions};
use crate::linalg::Mat;
use crate::metrics::Trace;
use crate::model::NmfModel;
use crate::samplers::{run_sampler, GibbsPoisson, Ld, Psgld};
use crate::Result;

pub struct Fig3Row {
    pub method: &'static str,
    pub seconds: f64,
    pub recovery: f64,
    pub final_loglik: f64,
}

/// Dump a dictionary (I × K) as CSV for visual inspection.
fn dump_dictionary(path: &std::path::Path, w: &Mat) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "bin")?;
    for k in 0..w.cols() {
        write!(f, ",template_{k}")?;
    }
    writeln!(f)?;
    for i in 0..w.rows() {
        write!(f, "{i}")?;
        for k in 0..w.cols() {
            write!(f, ",{}", w.get(i, k))?;
        }
        writeln!(f)?;
    }
    Ok(())
}

pub fn fig3(opts: &ExpOptions) -> Result<Vec<Fig3Row>> {
    let (bins, frames, k, b) = (256, 256, 8, 8);
    let t = opts.t(2_000, 10_000);
    let burn = t / 2;
    let data = audio::piano_spectrogram(bins, frames, opts.seed);
    let w_true = data.w_true.as_ref().expect("synthetic");
    let model = NmfModel::poisson(k);
    let mut rows = Vec::new();
    let mut traces: Vec<Trace> = Vec::new();

    // PSGLD
    let run = RunConfig::quick(t).with_step(StepSchedule::Polynomial { a: 5e-4, b: 0.51 });
    let mut p = Psgld::new(&data.v, &model, b, run.clone(), opts.seed);
    let res = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    let w_mean = res.posterior.w_mean();
    dump_dictionary(&opts.csv_path("fig3_dictionary_psgld.csv"), &w_mean)?;
    rows.push(Fig3Row {
        method: "psgld",
        seconds: res.sampling_seconds,
        recovery: audio::dictionary_recovery_score(&w_mean, w_true),
        final_loglik: res.trace.last_value(),
    });
    traces.push(res.trace);

    // LD
    let run_ld = RunConfig::quick(t).with_step(StepSchedule::Constant { eps: 1e-5 });
    let mut ld = Ld::new(&data.v, &model, run_ld.step, opts.seed + 1);
    let res = run_sampler(&mut ld, &run_ld, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    let w_mean = res.posterior.w_mean();
    dump_dictionary(&opts.csv_path("fig3_dictionary_ld.csv"), &w_mean)?;
    rows.push(Fig3Row {
        method: "ld",
        seconds: res.sampling_seconds,
        recovery: audio::dictionary_recovery_score(&w_mean, w_true),
        final_loglik: res.trace.last_value(),
    });
    traces.push(res.trace);

    // Gibbs (reference timing; fewer iterations, extrapolated)
    if opts.gibbs {
        let gibbs_t = if opts.full { t / 10 } else { (t / 50).max(10) };
        let run_g = RunConfig::quick(gibbs_t);
        let mut g = GibbsPoisson::new(&data.v, &model, opts.seed + 2);
        let res = run_sampler(&mut g, &run_g, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        let w_mean = res.posterior.w_mean();
        rows.push(Fig3Row {
            method: "gibbs",
            seconds: res.sampling_seconds * t as f64 / gibbs_t as f64,
            recovery: audio::dictionary_recovery_score(&w_mean, w_true),
            final_loglik: res.trace.last_value(),
        });
        traces.push(res.trace);
    }

    let trace_refs: Vec<&Trace> = traces.iter().collect();
    save_traces(&opts.csv_path("fig3_traces.csv"), &trace_refs)?;

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.to_string(),
                fmt_s(r.seconds),
                format!("{:.3}", r.recovery),
                format!("{:.3e}", r.final_loglik),
            ]
        })
        .collect();
    print_table(
        &format!("Fig 3 audio decomposition (256x256, K=8, T={t}, burn-in {burn})"),
        &["method", "time(T iters)", "template recovery", "final loglik"],
        &table,
    );
    Ok(rows)
}
