//! Ablations over the design choices DESIGN.md §5 calls out:
//!   1. part schedule: cyclic vs random-shift vs random-perm;
//!   2. mirroring on/off (β = 2, where both are well-defined);
//!   3. Langevin noise on/off (PSGLD vs DSGD posterior spread);
//!   4. grid size B sensitivity at fixed data;
//!   5. backend: native stripes vs batched-HLO dispatch per-iteration
//!      cost.

use std::time::Instant;

use crate::config::{RunConfig, StepSchedule};
use crate::coordinator::HloPsgld;
use crate::data::synth;
use crate::experiments::common::{fmt_s, print_table, ExpOptions};
use crate::model::NmfModel;
use crate::partition::PartSchedule;
use crate::samplers::{run_sampler, Psgld, Sampler};
use crate::Result;

pub fn schedule_ablation(opts: &ExpOptions) -> Result<()> {
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, opts.seed);
    let t = opts.t(500, 5_000);
    let mut rows = Vec::new();
    for (name, sched) in [
        ("cyclic", PartSchedule::Cyclic),
        ("random_shift", PartSchedule::RandomShift),
        ("random_perm", PartSchedule::RandomPerm),
    ] {
        let run = RunConfig::quick(t)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
            .with_schedule(sched);
        let mut p = Psgld::new(&data.v, &model, 4, run.clone(), opts.seed);
        let res = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        rows.push(vec![
            name.to_string(),
            format!("{:.4e}", res.trace.mean_after(t / 2)),
            fmt_s(res.sampling_seconds),
        ]);
    }
    print_table(
        "Ablation: part schedule (Condition 2 variants)",
        &["schedule", "post-burn-in loglik", "time"],
        &rows,
    );
    Ok(())
}

pub fn mirroring_ablation(opts: &ExpOptions) -> Result<()> {
    // Gaussian model: mirrored vs free chains both sample; the mirrored
    // one keeps the state non-negative.
    let mut model = NmfModel::gaussian(16);
    model.lam_w = 1.0;
    model.lam_h = 1.0;
    let data = synth::from_model(128, 128, &model, opts.seed);
    let t = opts.t(400, 4_000);
    let mut rows = Vec::new();
    for mirror in [true, false] {
        let mut m = model.clone();
        m.mirror = mirror;
        // Gaussian gradients lack the 1/mu damping of the Poisson case
        // (e grows with mu itself), so the stable step band sits orders
        // of magnitude lower than the Poisson experiments'.
        let run = RunConfig::quick(t)
            .with_step(StepSchedule::Polynomial { a: 1e-7, b: 0.51 });
        let mut p = Psgld::new(&data.v, &m, 4, run.clone(), opts.seed);
        let res = run_sampler(&mut p, &run, |s| m.loglik_dense(&s.w, &s.h(), &data.v));
        let negatives = p
            .state()
            .w
            .as_slice()
            .iter()
            .filter(|&&x| x < 0.0)
            .count();
        rows.push(vec![
            if mirror { "mirrored" } else { "free" }.into(),
            format!("{:.4e}", res.trace.last_value()),
            negatives.to_string(),
        ]);
    }
    print_table(
        "Ablation: mirroring step (beta = 2)",
        &["variant", "final loglik", "negative W entries"],
        &rows,
    );
    Ok(())
}

pub fn b_sensitivity(opts: &ExpOptions) -> Result<()> {
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, opts.seed);
    let t = opts.t(500, 5_000);
    let mut rows = Vec::new();
    for b in [2usize, 4, 8, 16, 32] {
        let run = RunConfig::quick(t)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
        let mut p = Psgld::new(&data.v, &model, b, run.clone(), opts.seed);
        let res = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        rows.push(vec![
            b.to_string(),
            format!("{:.4e}", res.trace.mean_after(t / 2)),
            fmt_s(res.sampling_seconds),
        ]);
    }
    print_table(
        "Ablation: grid size B (128x128, K=16)",
        &["B", "post-burn-in loglik", "time"],
        &rows,
    );
    crate::log_info!("  note: per iteration PSGLD touches N/B entries, so larger B is\n  cheaper per iteration but needs B iterations per data sweep.");
    Ok(())
}

pub fn backend_ablation(opts: &ExpOptions) -> Result<()> {
    if !opts.has_artifacts() {
        crate::log_warn!("  (skipped: run `make artifacts` for the HLO backend)");
        return Ok(());
    }
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, opts.seed);
    let t = opts.t(200, 2_000);
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

    let mut native = Psgld::new(&data.v, &model, 4, run.clone(), opts.seed);
    let tick = Instant::now();
    for i in 1..=t {
        native.step(i);
    }
    let native_s = tick.elapsed().as_secs_f64();

    let mut hlo = HloPsgld::new(&opts.artifacts, &data.v, &model, 4, run.clone(), opts.seed)?;
    hlo.step(1); // absorb compile cost outside the timed loop
    let tick = Instant::now();
    for i in 2..=t {
        hlo.step(i);
    }
    let hlo_s = tick.elapsed().as_secs_f64();

    print_table(
        "Ablation: update backend (128x128, K=16, B=4)",
        &["backend", "time", "per-iteration"],
        &[
            vec!["native stripes".into(), fmt_s(native_s), fmt_s(native_s / t as f64)],
            vec![
                "batched HLO".into(),
                fmt_s(hlo_s),
                fmt_s(hlo_s / (t - 1) as f64),
            ],
        ],
    );
    Ok(())
}

pub fn run_all(opts: &ExpOptions) -> Result<()> {
    schedule_ablation(opts)?;
    mirroring_ablation(opts)?;
    b_sensitivity(opts)?;
    backend_ablation(opts)?;
    Ok(())
}
