//! Fig. 5 — MovieLens-10M RMSE vs iteration, PSGLD vs DSGD, K = 50,
//! β = φ = 1, B = 15, 1000 iterations.
//!
//! The real MovieLens file is loaded when present
//! (`data/ml-10m/ratings.dat`); otherwise the statistically matched
//! synthetic generator is used (DESIGN.md §3). Both methods run on the
//! identical sparse workload with identical partitioning — the measured
//! delta is exactly the Langevin noise, which is the paper's point: the
//! sampler is as fast as the optimiser.

use crate::config::{RunConfig, StepSchedule};
use crate::data::movielens;
use crate::data::sparse::Csr;
use crate::experiments::common::{fmt_s, print_table, save_traces, ExpOptions};
use crate::metrics::{rmse_sparse, Trace};
use crate::model::NmfModel;
use crate::samplers::{run_sampler, Dsgd, Psgld};
use crate::Result;

pub struct Fig5Row {
    pub method: &'static str,
    pub seconds: f64,
    pub final_rmse: f64,
}

/// Load the real dataset when available, else generate the synthetic
/// MovieLens-like matrix at `scale`.
pub fn load_or_generate(scale: f64, k: usize, seed: u64) -> (Csr, &'static str) {
    let real = std::path::Path::new("data/ml-10m/ratings.dat");
    if real.exists() {
        if let Ok(csr) = movielens::load_movielens(real) {
            return (csr, "movielens-10m (real)");
        }
    }
    (movielens::movielens_like(scale, k, seed), "movielens-like (synthetic)")
}

pub fn fig5(opts: &ExpOptions) -> Result<Vec<Fig5Row>> {
    let k = 50;
    let b = 15;
    let t = opts.t(300, 1_000);
    let scale = if opts.full { 1.0 } else { 0.08 };
    let (csr, source) = load_or_generate(scale, k, opts.seed);
    crate::log_info!(
        "  dataset: {source}: {} x {} with {} ratings",
        csr.rows(),
        csr.cols(),
        csr.nnz()
    );
    // match the prior scale to the data: E[mu] = K/(lam_w lam_h) = mean(V)
    let lam = (k as f64 / csr.mean()).sqrt() as f32;
    let model = NmfModel::poisson(k).with_priors(lam, lam);
    let step = StepSchedule::Polynomial { a: 1e-3, b: 0.51 };
    let run = RunConfig::quick(t)
        .with_step(step)
        .with_monitor_every((t / 50).max(1));

    let mut rows = Vec::new();
    let mut traces: Vec<Trace> = Vec::new();

    let mut p = Psgld::new_sparse(&csr, &model, b, run.clone(), opts.seed)?;
    let res = run_sampler(&mut p, &run, |s| rmse_sparse(&s.w, &s.h(), &csr));
    rows.push(Fig5Row {
        method: "psgld",
        seconds: res.sampling_seconds,
        final_rmse: res.trace.last_value(),
    });
    traces.push(res.trace);

    let mut d = Dsgd::new_sparse(&csr, &model, b, run.clone(), opts.seed)?;
    let res = run_sampler(&mut d, &run, |s| rmse_sparse(&s.w, &s.h(), &csr));
    rows.push(Fig5Row {
        method: "dsgd",
        seconds: res.sampling_seconds,
        final_rmse: res.trace.last_value(),
    });
    traces.push(res.trace);

    let trace_refs: Vec<&Trace> = traces.iter().collect();
    save_traces(&opts.csv_path("fig5_rmse.csv"), &trace_refs)?;

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.to_string(),
                fmt_s(r.seconds),
                format!("{:.4}", r.final_rmse),
            ]
        })
        .collect();
    print_table(
        &format!("Fig 5 RMSE on {source} (K={k}, B={b}, T={t})"),
        &["method", "time", "final RMSE"],
        &table,
    );
    crate::log_info!(
        "  paper's claim: PSGLD converges like DSGD at the same speed; \
         time ratio psgld/dsgd = {:.2}",
        rows[0].seconds / rows[1].seconds.max(1e-12)
    );
    Ok(rows)
}
