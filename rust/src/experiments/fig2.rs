//! Fig. 2 — shared-memory synthetic-data comparison.
//!
//! (a) Poisson-NMF: mixing (loglik vs iteration) + total running times
//!     for Gibbs / LD / SGLD / PSGLD at I = J ∈ {256, 512, 1024}, K=32,
//!     B = I/32, |Ω| = IJ/32.
//! (b) compound-Poisson (β = 0.5, φ = 1): LD / SGLD / PSGLD at
//!     I = J = 1024.
//!
//! Paper-reported step sizes: LD ε = 0.2, SGLD (a=1, b=0.51), PSGLD
//! (a=0.01, b=0.51) — those assume the authors' gradient scaling; with
//! our unnormalised gradients the same *relative* ordering holds at the
//! per-experiment constants below (documented in EXPERIMENTS.md).

use crate::config::{RunConfig, StepSchedule};
use crate::coordinator::HloPsgld;
use crate::data::synth;
use crate::experiments::common::{fmt_s, print_table, save_traces, ExpOptions};
use crate::metrics::Trace;
use crate::model::NmfModel;
use crate::samplers::{run_sampler, GibbsPoisson, Ld, Psgld, RunResult, Sgld};
use crate::Result;

/// One method's outcome at one problem size.
pub struct MethodRow {
    pub method: &'static str,
    pub size: usize,
    pub seconds: f64,
    pub final_loglik: f64,
    pub trace: Trace,
}

fn record(method: &'static str, size: usize, res: RunResult) -> MethodRow {
    MethodRow {
        method,
        size,
        seconds: res.sampling_seconds,
        final_loglik: res.trace.last_value(),
        trace: res.trace,
    }
}

/// Run Fig. 2(a) at one size; returns one row per method.
pub fn fig2a_at_size(opts: &ExpOptions, i: usize, t: u64, gibbs_t: u64) -> Result<Vec<MethodRow>> {
    let k = 32;
    let b = i / 32;
    let model = NmfModel::poisson(k);
    let data = synth::poisson_nmf(i, i, &model, opts.seed);
    let monitor_every = (t / 50).max(1);
    let mut rows = Vec::new();

    // PSGLD (native). The drift per entry scales with the N/|Pi| = B
    // factor; with eps_t = (a/t)^0.51, keeping eps*B constant requires
    // a scaled by B^(-1/0.51) ~ B^-2 (a = 0.002 at the B = 8 reference).
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.12 / (b * b) as f64, b: 0.51 })
        .with_monitor_every(monitor_every);
    let mut p = Psgld::new(&data.v, &model, b, run.clone(), opts.seed);
    let res = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("psgld", i, res));

    // PSGLD (HLO backend), if artifacts cover this geometry
    if opts.has_artifacts() {
        if let Ok(mut hlo) =
            HloPsgld::new(&opts.artifacts, &data.v, &model, b, run.clone(), opts.seed)
        {
            let res =
                run_sampler(&mut hlo, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
            rows.push(record("psgld_hlo", i, res));
        }
    }

    // LD
    let run_ld = RunConfig::quick(t)
        .with_step(StepSchedule::Constant { eps: 2e-5 })
        .with_monitor_every(monitor_every);
    let mut ld = Ld::new(&data.v, &model, run_ld.step, opts.seed + 1);
    let res = run_sampler(&mut ld, &run_ld, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("ld", i, res));

    // SGLD, |Ω| = IJ/32
    let run_sgld = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 1e-4, b: 0.51 })
        .with_monitor_every(monitor_every);
    let mut sgld = Sgld::new(&data.v, &model, i * i / 32, run_sgld.step, opts.seed + 2);
    let res =
        run_sampler(&mut sgld, &run_sgld, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("sgld", i, res));

    // Gibbs (run gibbs_t iterations; per-iteration cost is flat, so the
    // T-iteration time is extrapolated linearly for the summary).
    if opts.gibbs && gibbs_t > 0 {
        let run_g = RunConfig::quick(gibbs_t).with_monitor_every((gibbs_t / 25).max(1));
        let mut g = GibbsPoisson::new(&data.v, &model, opts.seed + 3);
        let res = run_sampler(&mut g, &run_g, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        let mut row = record("gibbs", i, res);
        row.seconds *= t as f64 / gibbs_t as f64; // extrapolate to T
        rows.push(row);
    }

    Ok(rows)
}

/// Full Fig. 2(a) harness.
pub fn fig2a(opts: &ExpOptions) -> Result<Vec<MethodRow>> {
    let t = opts.t(2_000, 10_000);
    let sizes: &[usize] = if opts.full { &[256, 512, 1024] } else { &[256, 512] };
    let mut all = Vec::new();
    for &i in sizes {
        // Gibbs cost explodes with size; sub-sample its iteration count
        let gibbs_t = if opts.full { t / 20 } else { (t / 40).max(10) };
        let rows = fig2a_at_size(opts, i, t, gibbs_t)?;
        let traces: Vec<&Trace> = rows.iter().map(|r| &r.trace).collect();
        save_traces(&opts.csv_path(&format!("fig2a_i{i}.csv")), &traces)?;
        all.extend(rows);
    }
    summarize("Fig 2(a) Poisson-NMF (T-iteration running time)", &all, t);
    Ok(all)
}

/// Fig. 2(b): compound-Poisson observation model.
pub fn fig2b(opts: &ExpOptions) -> Result<Vec<MethodRow>> {
    let t = opts.t(1_000, 10_000);
    let i = if opts.full { 1024 } else { 512 };
    let k = 32;
    let model = NmfModel::compound_poisson(k);
    let data = synth::compound_poisson_nmf(i, i, &model, opts.seed);
    let monitor_every = (t / 50).max(1);
    let mut rows = Vec::new();

    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.12 / ((i / 32) * (i / 32)) as f64, b: 0.51 })
        .with_monitor_every(monitor_every);
    let mut p = Psgld::new(&data.v, &model, i / 32, run.clone(), opts.seed);
    let res = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("psgld", i, res));

    let run_ld = RunConfig::quick(t)
        .with_step(StepSchedule::Constant { eps: 2e-5 })
        .with_monitor_every(monitor_every);
    let mut ld = Ld::new(&data.v, &model, run_ld.step, opts.seed + 1);
    let res = run_sampler(&mut ld, &run_ld, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("ld", i, res));

    let run_sgld = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 1e-4, b: 0.51 })
        .with_monitor_every(monitor_every);
    let mut sgld = Sgld::new(&data.v, &model, i * i / 32, run_sgld.step, opts.seed + 2);
    let res =
        run_sampler(&mut sgld, &run_sgld, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    rows.push(record("sgld", i, res));

    let traces: Vec<&Trace> = rows.iter().map(|r| &r.trace).collect();
    save_traces(&opts.csv_path(&format!("fig2b_i{i}.csv")), &traces)?;
    summarize("Fig 2(b) compound-Poisson (beta = 0.5)", &rows, t);
    Ok(rows)
}

fn summarize(title: &str, rows: &[MethodRow], t: u64) {
    let mut table = Vec::new();
    for r in rows {
        // speedup of PSGLD over this method at the same size
        let psgld_s = rows
            .iter()
            .find(|x| x.method == "psgld" && x.size == r.size)
            .map(|x| x.seconds)
            .unwrap_or(f64::NAN);
        table.push(vec![
            r.size.to_string(),
            r.method.to_string(),
            fmt_s(r.seconds),
            format!("{:.3e}", r.final_loglik),
            if r.method == "psgld" {
                "1.0x".into()
            } else {
                format!("{:.0}x", r.seconds / psgld_s)
            },
        ]);
    }
    print_table(
        &format!("{title}, T = {t}"),
        &["I=J", "method", "time(T iters)", "final loglik", "PSGLD speedup"],
        &table,
    );
}
