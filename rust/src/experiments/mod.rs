//! Experiment harnesses — one per table/figure of the paper's
//! evaluation (§4), regenerating the same series/rows at configurable
//! scale. Each writes CSVs under an output directory and prints the
//! summary lines the paper reports. See DESIGN.md §5 for the index.

pub mod ablations;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig5;
pub mod fig6;

pub use common::ExpOptions;
