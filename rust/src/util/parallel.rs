//! Data parallelism for the block samplers (rayon is unavailable
//! offline).
//!
//! Two regimes live here:
//!
//! * [`WorkerPool`] — a **persistent** pool: threads are created once
//!   (per sampler), park on a condvar between iterations and are woken
//!   through an epoch barrier. Work is handed over as disjoint indexed
//!   tasks (the caller guarantees index-disjoint mutation, exactly the
//!   stripe-slice safety story of the PSGLD driver), so the steady-state
//!   `step()` costs two condvar transitions instead of B thread
//!   spawn/joins. Each worker slot owns a [`ScratchArena`] that the
//!   kernels reuse across iterations — the allocation-free hot path.
//! * [`par_for_each_mut`] / [`par_map`] — the original spawn-per-call
//!   scoped-thread versions, kept as the baseline the benches compare
//!   against (`ExecMode::Spawn`) and for one-shot callers.
//!
//! Determinism contract: a task's result may depend only on its index,
//! never on which worker slot ran it. Arena contents are garbage between
//! tasks (kernels must fully overwrite before reading), which makes the
//! chain bitwise identical across 1/2/N workers and pool-vs-inline.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::obs::{counter_add, Counter, Phase, Span};

/// Hard ceiling on the default worker count ("so tests stay snappy" —
/// and because B rarely exceeds this on one host). Raise per-run with
/// the `PALLAS_THREADS` environment variable or `with_threads`.
pub const DEFAULT_THREAD_CAP: usize = 16;

/// Number of worker threads to use by default: `PALLAS_THREADS` if set
/// (uncapped), else the machine's available parallelism capped at
/// [`DEFAULT_THREAD_CAP`].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(DEFAULT_THREAD_CAP)
}

// ---------------------------------------------------------------------------
// Scratch arenas
// ---------------------------------------------------------------------------

/// Grow-only f32 scratch owned by one worker slot. Kernels carve views
/// out of it per task; it only allocates while growing towards the
/// high-water mark, after which the steady state is allocation-free.
#[derive(Default)]
pub struct ScratchArena {
    buf: Vec<f32>,
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena { buf: Vec::new() }
    }

    /// Current capacity high-water mark (in f32 elements).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Three disjoint views of `a + b + c` elements. Contents are
    /// arbitrary (whatever the previous task left); callers must fully
    /// initialise before reading.
    pub fn take3(&mut self, a: usize, b: usize, c: usize) -> (&mut [f32], &mut [f32], &mut [f32]) {
        let need = a + b + c;
        if self.buf.len() < need {
            self.buf.resize(need, 0.0);
        }
        let (xa, rest) = self.buf.split_at_mut(a);
        let (xb, rest) = rest.split_at_mut(b);
        (xa, xb, &mut rest[..c])
    }

    /// One view of `n` elements (same garbage-contents contract as
    /// [`take3`]). Used for the SGLD noise slab.
    pub fn take(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        &mut self.buf[..n]
    }
}

/// Run `f` with this thread's private [`ScratchArena`]. The arena is
/// grow-only and lives for the thread's lifetime, so repeated calls from
/// the same thread are allocation-free once the high-water mark is
/// reached — this is what backs the one-shot kernel wrappers
/// (`grads_dense_core`, the `Mat` SGLD wrapper) without changing their
/// signatures.
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut ScratchArena) -> R) -> R {
    thread_local! {
        static SCRATCH: std::cell::RefCell<ScratchArena> =
            std::cell::RefCell::new(ScratchArena::new());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Covariant raw-pointer wrapper that asserts cross-thread safety. Used
/// by the samplers to hand base pointers of the factor matrices into
/// pool tasks; the tasks derive disjoint stripes from them (disjointness
/// follows from the part permutation being a bijection).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    pub fn get(self) -> *mut T {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Type-erased job: a borrowed `Fn(worker_slot)` whose lifetime is
/// erased to 'static. Sound because the submitting thread blocks inside
/// [`WorkerPool::run`] until every worker has finished with it.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
}

unsafe impl Send for Job {}

fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> Job {
    let raw: *const (dyn Fn(usize) + Sync + 'a) = f;
    // SAFETY: pure lifetime erasure on a fat raw pointer; the pointee is
    // only dereferenced while `run` (which holds the real borrow) blocks.
    Job { f: unsafe { std::mem::transmute::<*const (dyn Fn(usize) + Sync + 'a), *const (dyn Fn(usize) + Sync + 'static)>(raw) } }
}

struct JobState {
    /// Bumped once per published job; workers run each epoch once.
    epoch: u64,
    /// Helper threads still running the current epoch's job.
    remaining: usize,
    /// A helper panicked during the current epoch.
    panicked: bool,
    shutdown: bool,
    job: Option<Job>,
}

/// One worker slot's arena, accessed by exactly one thread per epoch.
struct SyncCell<T>(UnsafeCell<T>);

// SAFETY: access discipline is one-thread-per-slot-per-epoch, enforced
// by the epoch barrier (helpers) and `&mut self` methods (caller).
unsafe impl<T: Send> Sync for SyncCell<T> {}

struct PoolShared {
    state: Mutex<JobState>,
    work_cv: Condvar,
    done_cv: Condvar,
    scratch: Vec<SyncCell<ScratchArena>>,
}

fn lock(m: &Mutex<JobState>) -> MutexGuard<'_, JobState> {
    // a worker panic poisons the mutex; the flag-based protocol below
    // stays consistent regardless, so poisoning carries no information
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Persistent worker pool with an epoch barrier. `width` counts the
/// calling thread: a pool of width `n` owns `n - 1` parked helper
/// threads and the caller executes slot 0's share in [`run`]. Width 1
/// degenerates to inline execution with zero synchronisation.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    width: usize,
}

impl WorkerPool {
    /// Create a pool of total width `threads` (`threads - 1` parked
    /// helpers + the caller).
    pub fn new(threads: usize) -> Self {
        let width = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(JobState {
                epoch: 0,
                remaining: 0,
                panicked: false,
                shutdown: false,
                job: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            scratch: (0..width).map(|_| SyncCell(UnsafeCell::new(ScratchArena::new()))).collect(),
        });
        let handles = (1..width)
            .map(|slot| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("pallas-worker-{slot}"))
                    .spawn(move || worker_loop(shared, slot))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, width }
    }

    /// Total worker count, including the calling thread.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Run `f(arena, i)` for every `i in 0..n`, distributed round-robin
    /// over the pool (`i % width == slot`). Blocks until all indices
    /// completed. `&mut self` serialises submissions, which is what
    /// makes the one-thread-per-slot arena discipline sound.
    pub fn for_each_index(&mut self, n: usize, f: impl Fn(&mut ScratchArena, usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.width == 1 || n == 1 {
            self.for_each_index_inline(n, f);
            return;
        }
        let width = self.width;
        let shared: &PoolShared = &self.shared;
        let job = move |slot: usize| {
            // One span per slot share per epoch (not per index) — the
            // span cost amortises over the slot's whole stride.
            let _task_span = Span::enter(Phase::PoolTask, "pool_slot");
            // SAFETY: slot is driven by exactly one thread this epoch
            let arena = unsafe { &mut *shared.scratch[slot].0.get() };
            let mut i = slot;
            while i < n {
                f(arena, i);
                i += width;
            }
        };
        self.run(&job);
    }

    /// Sequential variant on the calling thread (slot 0's arena), used
    /// for `ExecMode::Inline` and the width-1 fast path. Numerically
    /// identical to the parallel path by the determinism contract.
    pub fn for_each_index_inline(&mut self, n: usize, f: impl Fn(&mut ScratchArena, usize)) {
        let arena = unsafe { &mut *self.shared.scratch[0].0.get() };
        for i in 0..n {
            f(arena, i);
        }
    }

    /// Parallel map over owned items, preserving input order.
    pub fn map<T: Send, R: Send>(
        &mut self,
        items: Vec<T>,
        f: impl Fn(usize, T) -> R + Sync,
    ) -> Vec<R> {
        let mut slots: Vec<(Option<T>, Option<R>)> =
            items.into_iter().map(|t| (Some(t), None)).collect();
        let n = slots.len();
        let base = SendPtr::new(slots.as_mut_ptr());
        self.for_each_index(n, |_arena, i| {
            // SAFETY: each index is visited exactly once; slots are
            // disjoint by index
            let slot = unsafe { &mut *base.get().add(i) };
            let t = slot.0.take().expect("item present");
            slot.1 = Some(f(i, t));
        });
        slots.into_iter().map(|s| s.1.expect("result present")).collect()
    }

    /// Publish a job, run slot 0's share on the caller, block until the
    /// helpers drain, then propagate any panic. Private and only reached
    /// through `&mut self` entry points, so submissions are serialised.
    fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        debug_assert!(self.width > 1);
        counter_add(Counter::PoolEpochs, 1);
        {
            let mut st = lock(&self.shared.state);
            debug_assert_eq!(st.remaining, 0, "previous epoch drained");
            st.job = Some(erase(job));
            st.remaining = self.width - 1;
            st.epoch = st.epoch.wrapping_add(1);
            self.shared.work_cv.notify_all();
        }
        // the caller is worker slot 0; catch so a caller-side panic
        // still waits for the helpers (they borrow `job`)
        let caller = catch_unwind(AssertUnwindSafe(|| job(0)));
        let worker_panicked = {
            let mut st = lock(&self.shared.state);
            while st.remaining > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if worker_panicked {
            panic!("worker pool: a worker thread panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, slot: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("job published with epoch bump");
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // SAFETY: `run` keeps the pointee alive until `remaining == 0`
        let f = unsafe { &*job.f };
        let outcome = catch_unwind(AssertUnwindSafe(|| f(slot)));
        let mut st = lock(&shared.state);
        if outcome.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Spawn-per-call baseline (legacy)
// ---------------------------------------------------------------------------

/// Apply `f` to every element of `items` in parallel using at most
/// `threads` **freshly spawned** scoped threads. This is the
/// spawn-per-call regime the persistent pool replaces on the hot path;
/// kept as the measured baseline (`ExecMode::Spawn`, fig6 bench) and for
/// one-shot callers.
pub fn par_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let f = &f;
    // round-robin assignment of items to threads
    std::thread::scope(|scope| {
        let mut slots: Vec<Vec<(usize, &mut T)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, item) in items.iter_mut().enumerate() {
            slots[i % threads].push((i, item));
        }
        for slot in slots {
            scope.spawn(move || {
                for (i, item) in slot {
                    f(i, item);
                }
            });
        }
    });
}

/// Parallel map producing a `Vec<R>` in input order (spawn-per-call).
pub fn par_map<T: Send, R: Send>(
    items: Vec<T>,
    threads: usize,
    f: impl Fn(usize, T) -> R + Sync,
) -> Vec<R> {
    let mut slots: Vec<(usize, Option<T>, Option<R>)> = items
        .into_iter()
        .enumerate()
        .map(|(i, t)| (i, Some(t), None))
        .collect();
    par_for_each_mut(&mut slots, threads, |_, slot| {
        let t = slot.1.take().expect("item present");
        slot.2 = Some(f(slot.0, t));
    });
    slots.into_iter().map(|s| s.2.expect("result present")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_each_mut_touches_every_item_once() {
        let mut items: Vec<usize> = vec![0; 37];
        par_for_each_mut(&mut items, 4, |i, x| {
            *x += i + 1;
        });
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn par_for_each_mut_single_thread_path() {
        let mut items = vec![1, 2, 3];
        par_for_each_mut(&mut items, 1, |_, x| *x *= 10);
        assert_eq!(items, vec![10, 20, 30]);
    }

    #[test]
    fn par_for_each_runs_concurrently_when_asked() {
        let counter = AtomicUsize::new(0);
        let mut items = vec![(); 8];
        par_for_each_mut(&mut items, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..23).collect();
        let out = par_map(items, 5, |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..23).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_zero_threads_are_safe() {
        let mut empty: Vec<u8> = vec![];
        par_for_each_mut(&mut empty, 0, |_, _| {});
        let mut one = vec![5u8];
        par_for_each_mut(&mut one, 0, |_, x| *x += 1);
        assert_eq!(one[0], 6);
    }

    // ---- persistent pool -------------------------------------------------

    #[test]
    fn pool_runs_every_index_exactly_once() {
        let mut pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(37, |_, i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // pool is reusable across epochs
        pool.for_each_index(37, |_, i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 2));
    }

    #[test]
    fn pool_map_preserves_order_and_is_reusable() {
        let mut pool = WorkerPool::new(3);
        for round in 0..3usize {
            let out = pool.map((0..23).collect::<Vec<usize>>(), |i, x| {
                assert_eq!(i, x);
                x * 2 + round
            });
            assert_eq!(out, (0..23).map(|x| x * 2 + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_width_one_and_empty_are_inline() {
        let mut pool = WorkerPool::new(1);
        assert_eq!(pool.width(), 1);
        let mut seen = Vec::new();
        let base = SendPtr::new(&mut seen as *mut Vec<usize>);
        pool.for_each_index(5, |_, i| unsafe { (*base.get()).push(i) });
        assert_eq!(seen, vec![0, 1, 2, 3, 4]); // in order: inline path
        pool.for_each_index(0, |_, _| unreachable!());
    }

    #[test]
    fn pool_matches_inline_execution() {
        // determinism contract: same results regardless of worker count
        let compute = |i: usize| (i as f64 * 0.37).sin();
        let run = |width: usize| -> Vec<f64> {
            let mut pool = WorkerPool::new(width);
            let mut out = vec![0.0f64; 41];
            let base = SendPtr::new(out.as_mut_ptr());
            pool.for_each_index(41, |_, i| unsafe {
                *base.get().add(i) = compute(i);
            });
            out
        };
        let a = run(1);
        let b = run(2);
        let c = run(5);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let mut pool = WorkerPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.for_each_index(8, |_, i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // the pool stays usable after a panic
        let counter = AtomicUsize::new(0);
        pool.for_each_index(8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scratch_arena_grows_and_reuses() {
        let mut arena = ScratchArena::new();
        {
            let (a, b, c) = arena.take3(3, 4, 5);
            assert_eq!((a.len(), b.len(), c.len()), (3, 4, 5));
            a.fill(1.0);
            b.fill(2.0);
            c.fill(3.0);
        }
        assert_eq!(arena.len(), 12);
        // smaller request reuses the same buffer (no shrink)
        let (a, _, _) = arena.take3(2, 2, 2);
        assert_eq!(a, &[1.0, 1.0]); // old contents visible: views are raw
        assert_eq!(arena.len(), 12);
    }

    #[test]
    fn take_single_view_and_thread_scratch_reuse() {
        let mut arena = ScratchArena::new();
        arena.take(8).fill(7.0);
        assert_eq!(arena.len(), 8);
        // shrinking request reuses the buffer and exposes old contents
        assert_eq!(arena.take(4), &[7.0; 4]);
        assert_eq!(arena.len(), 8);

        let first = with_thread_scratch(|s| {
            s.take(16).fill(1.0);
            s.len()
        });
        // the same thread gets the same (already grown) arena back
        let second = with_thread_scratch(|s| s.len());
        assert_eq!(first, 16);
        assert_eq!(second, 16);
    }

    #[test]
    fn default_threads_cap_and_env_override() {
        std::env::set_var("PALLAS_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::set_var("PALLAS_THREADS", "not-a-number");
        let fallback = default_threads();
        assert!(fallback >= 1 && fallback <= DEFAULT_THREAD_CAP);
        std::env::remove_var("PALLAS_THREADS");
        let n = default_threads();
        assert!(n >= 1 && n <= DEFAULT_THREAD_CAP);
    }
}
