//! Scoped-thread data parallelism (rayon is unavailable offline).
//!
//! `par_map_mut` is what the shared-memory PSGLD driver needs: apply a
//! closure to B disjoint `&mut` work items (the blocks of a part) across
//! a bounded number of OS threads. Items are distributed round-robin;
//! with B ≤ threads each item gets its own thread, matching the paper's
//! one-thread-per-block GPU/OpenMP structure.

/// Number of worker threads to use by default (the machine's
/// parallelism, capped so tests stay snappy).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every element of `items` in parallel using at most
/// `threads` OS threads. Preserves ordering semantics trivially since
/// each element is processed exactly once via `&mut`.
pub fn par_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let f = &f;
    // round-robin assignment of items to threads
    std::thread::scope(|scope| {
        let mut slots: Vec<Vec<(usize, &mut T)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, item) in items.iter_mut().enumerate() {
            slots[i % threads].push((i, item));
        }
        for slot in slots {
            scope.spawn(move || {
                for (i, item) in slot {
                    f(i, item);
                }
            });
        }
    });
}

/// Parallel map producing a `Vec<R>` in input order.
pub fn par_map<T: Send, R: Send>(
    items: Vec<T>,
    threads: usize,
    f: impl Fn(usize, T) -> R + Sync,
) -> Vec<R> {
    let mut slots: Vec<(usize, Option<T>, Option<R>)> = items
        .into_iter()
        .enumerate()
        .map(|(i, t)| (i, Some(t), None))
        .collect();
    par_for_each_mut(&mut slots, threads, |_, slot| {
        let t = slot.1.take().expect("item present");
        slot.2 = Some(f(slot.0, t));
    });
    slots.into_iter().map(|s| s.2.expect("result present")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_each_mut_touches_every_item_once() {
        let mut items: Vec<usize> = vec![0; 37];
        par_for_each_mut(&mut items, 4, |i, x| {
            *x += i + 1;
        });
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn par_for_each_mut_single_thread_path() {
        let mut items = vec![1, 2, 3];
        par_for_each_mut(&mut items, 1, |_, x| *x *= 10);
        assert_eq!(items, vec![10, 20, 30]);
    }

    #[test]
    fn par_for_each_runs_concurrently_when_asked() {
        let counter = AtomicUsize::new(0);
        let mut items = vec![(); 8];
        par_for_each_mut(&mut items, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..23).collect();
        let out = par_map(items, 5, |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..23).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_zero_threads_are_safe() {
        let mut empty: Vec<u8> = vec![];
        par_for_each_mut(&mut empty, 0, |_, _| {});
        let mut one = vec![5u8];
        par_for_each_mut(&mut one, 0, |_, x| *x += 1);
        assert_eq!(one[0], 6);
    }
}
