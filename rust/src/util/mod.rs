//! In-crate substrates replacing unavailable third-party crates (the
//! build environment is fully offline — see DESIGN.md §"offline
//! substitutions"): JSON, a scoped thread pool, and a lightweight
//! property-testing harness.

pub mod json;
pub mod parallel;
pub mod prop;

pub use json::Json;
