//! Lightweight property-based testing harness (proptest is unavailable
//! offline). `forall` runs a property over `cases` randomly generated
//! inputs from a deterministic seed and reports the first failing case
//! with its case index and debug rendering, so failures are exactly
//! reproducible. No shrinking — generators should keep inputs small.

use crate::rng::Rng;

/// Run `prop` over `cases` inputs drawn by `gen`. Panics on the first
/// failure, printing the case index, seed and input.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Rng::derive(seed, &[case as u64]);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n{input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so it
/// can explain *why* it failed.
pub fn forall_explain<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::derive(seed, &[case as u64]);
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {why}\n{input:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Rng;

    /// Uniform integer in `[lo, hi]`.
    pub fn int_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        rng.uniform(lo as f64, hi as f64) as f32
    }

    /// Random vector of f32s in `[lo, hi)`.
    pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| f32_in(rng, lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("sum-commutes", 1, 50, |r| (r.next_f64(), r.next_f64()), |&(a, b)| {
            count += 1;
            a + b == b + a
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed at case 0")]
    fn failing_property_panics_with_case() {
        forall("always-fails", 2, 10, |r| r.next_u64(), |_| false);
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first: Vec<u64> = Vec::new();
        forall("collect", 3, 5, |r| r.next_u64(), |&x| {
            first.push(x);
            true
        });
        let mut second: Vec<u64> = Vec::new();
        forall("collect", 3, 5, |r| r.next_u64(), |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn gen_helpers_in_range() {
        let mut rng = crate::rng::Rng::seed_from(4);
        for _ in 0..1000 {
            let i = gen::int_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&i));
            let f = gen::f32_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        assert_eq!(gen::vec_f32(&mut rng, 7, 0.0, 1.0).len(), 7);
    }
}
