//! Minimal JSON substrate (the environment has no serde): a recursive-
//! descent parser and a pretty printer over a [`Json`] value tree.
//! Covers the full JSON grammar (strings with escapes, numbers, nested
//! containers); used by the artifact manifest loader and the config
//! system.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Config(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Config(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Config(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Config(format!("expected unsigned integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    /// Fetch a required object field.
    pub fn field(&self, name: &str) -> Result<&Json> {
        self.as_obj()?
            .get(name)
            .ok_or_else(|| Error::Config(format!("missing field '{name}'")))
    }

    /// Fetch an optional object field.
    pub fn field_opt(&self, name: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(name),
            _ => None,
        }
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- printing ----------------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .bytes
                        .get(self.pos)
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let combined = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        self.pos += len - 1;
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or_else(|| self.err("bad utf8"))?;
                        s.push_str(
                            std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("c").unwrap().as_str().unwrap(), "x");
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(!arr[2].field("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
        // non-ascii passthrough
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"x","vals":[1,2.5,-3],"flag":true,"none":null,"nested":{"s":"\"q\""}}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
        let back2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn integers_printed_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_obj().is_err());
        assert!(v.as_str().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::parse("{}").unwrap().field("missing").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
    }
}
