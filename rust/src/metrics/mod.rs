//! Monitoring: traces (value vs iteration vs wall/virtual time), RMSE
//! and log-likelihood monitors, effective sample size, and CSV output
//! for the figure harnesses.

pub mod diagnostics;
pub mod trace;

pub use diagnostics::{autocorrelation, gelman_rubin, geweke_z};
pub use trace::{NodeStats, SummaryStats, Trace};

use crate::data::sparse::Csr;
use crate::linalg::Mat;
use crate::model::tweedie;

/// RMSE between a dense V and |W||H| (Fig. 5's monitored quantity).
pub fn rmse_dense(w: &Mat, h: &Mat, v: &Mat) -> f64 {
    let mu = w.matmul_abs(h).expect("shape");
    let n = v.as_slice().len() as f64;
    let ss: f64 = v
        .as_slice()
        .iter()
        .zip(mu.as_slice())
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum();
    (ss / n).sqrt()
}

/// RMSE over the observed entries of a sparse V.
pub fn rmse_sparse(w: &Mat, h: &Mat, v: &Csr) -> f64 {
    let k = w.cols();
    debug_assert_eq!(h.rows(), k);
    let ht = h.transpose(); // cols x k, contiguous per column of H
    let mut ss = 0.0f64;
    for i in 0..v.rows() {
        let wrow = w.row(i);
        for (j, val) in v.row(i) {
            let hrow = ht.row(j as usize);
            let mut mu = 0f32;
            for kk in 0..k {
                mu += wrow[kk].abs() * hrow[kk].abs();
            }
            let d = (val - mu) as f64;
            ss += d * d;
        }
    }
    (ss / v.nnz() as f64).sqrt()
}

/// Unnormalised Tweedie log-likelihood over observed sparse entries.
pub fn loglik_sparse(w: &Mat, h: &Mat, v: &Csr, beta: f32, phi: f32) -> f64 {
    let k = w.cols();
    let ht = h.transpose();
    let mut ll = 0.0f64;
    for i in 0..v.rows() {
        let wrow = w.row(i);
        for (j, val) in v.row(i) {
            let hrow = ht.row(j as usize);
            let mut mu = 0f32;
            for kk in 0..k {
                mu += wrow[kk].abs() * hrow[kk].abs();
            }
            ll += tweedie::loglik_entry(val, mu + tweedie::MU_EPS, beta, phi) as f64;
        }
    }
    ll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn rmse_dense_zero_at_exact() {
        let mut rng = Rng::seed_from(1);
        let w = Mat::uniform(8, 3, 0.0, 1.0, &mut rng);
        let h = Mat::uniform(3, 8, 0.0, 1.0, &mut rng);
        let v = w.matmul_abs(&h).unwrap();
        assert!(rmse_dense(&w, &h, &v) < 1e-6);
    }

    #[test]
    fn rmse_sparse_matches_dense_on_full_pattern() {
        let mut rng = Rng::seed_from(2);
        let w = Mat::uniform(6, 2, 0.0, 1.0, &mut rng);
        let h = Mat::uniform(2, 5, 0.0, 1.0, &mut rng);
        let v = Mat::uniform(6, 5, 0.0, 2.0, &mut rng);
        let mut trip: Vec<(u32, u32, f32)> = Vec::new();
        for i in 0..6 {
            for j in 0..5 {
                trip.push((i as u32, j as u32, v.get(i, j)));
            }
        }
        let csr = Csr::from_triplets(6, 5, &mut trip).unwrap();
        let a = rmse_dense(&w, &h, &v);
        let b = rmse_sparse(&w, &h, &csr);
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn loglik_sparse_matches_dense_model() {
        use crate::model::NmfModel;
        let mut rng = Rng::seed_from(3);
        let model = NmfModel::poisson(2);
        let w = Mat::uniform(5, 2, 0.1, 1.0, &mut rng);
        let h = Mat::uniform(2, 4, 0.1, 1.0, &mut rng);
        let v = Mat::from_fn(5, 4, |i, j| ((i + j) % 3) as f32);
        let mut trip: Vec<(u32, u32, f32)> = Vec::new();
        for i in 0..5 {
            for j in 0..4 {
                trip.push((i as u32, j as u32, v.get(i, j)));
            }
        }
        let csr = Csr::from_triplets(5, 4, &mut trip).unwrap();
        let a = model.loglik_dense(&w, &h, &v);
        let b = loglik_sparse(&w, &h, &csr, 1.0, 1.0);
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}
