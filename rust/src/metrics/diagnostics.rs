//! MCMC convergence diagnostics: autocorrelation, Geweke's equality-of-
//! means test and the Gelman-Rubin potential scale reduction factor
//! (R̂) over parallel chains — the tooling a practitioner needs to
//! trust the sampler's output (the paper argues samplers beat point
//! estimates *because* they quantify uncertainty; these make that
//! quantification auditable).

/// Sample autocorrelation of `values` at lags `0..=max_lag`.
pub fn autocorrelation(values: &[f64], max_lag: usize) -> Vec<f64> {
    let n = values.len();
    if n < 2 {
        return vec![1.0];
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    let var: f64 = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let max_lag = max_lag.min(n - 1);
    (0..=max_lag)
        .map(|lag| {
            if var == 0.0 {
                return if lag == 0 { 1.0 } else { 0.0 };
            }
            let mut s = 0.0;
            for i in 0..n - lag {
                s += (values[i] - mean) * (values[i + lag] - mean);
            }
            s / (n as f64 * var)
        })
        .collect()
}

/// Integrated autocorrelation time via Geyer's initial positive
/// sequence (matches `SummaryStats::ess`: ESS = n / tau).
pub fn integrated_autocorr_time(values: &[f64]) -> f64 {
    let acf = autocorrelation(values, values.len() / 2);
    let mut tau = 1.0;
    let mut lag = 1;
    while lag + 1 < acf.len() {
        let pair = acf[lag] + acf[lag + 1];
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        lag += 2;
    }
    tau
}

/// Geweke (1992) diagnostic: z-score comparing the mean of the first
/// `frac_a` of the chain with the last `frac_b`, using spectral-density
/// variance estimates (here: batch means, adequate for monitoring).
/// |z| > 2 suggests the chain has not converged.
pub fn geweke_z(values: &[f64], frac_a: f64, frac_b: f64) -> f64 {
    let n = values.len();
    if n < 20 {
        return f64::NAN;
    }
    let na = ((n as f64 * frac_a) as usize).max(5);
    let nb = ((n as f64 * frac_b) as usize).max(5);
    let a = &values[..na];
    let b = &values[n - nb..];
    let mv = |x: &[f64]| {
        let m = x.iter().sum::<f64>() / x.len() as f64;
        // batch-means variance of the mean
        let nbatch = (x.len() as f64).sqrt() as usize;
        let bs = x.len() / nbatch.max(1);
        let means: Vec<f64> = x
            .chunks(bs.max(1))
            .filter(|c| c.len() == bs)
            .map(|c| c.iter().sum::<f64>() / c.len() as f64)
            .collect();
        let bm = means.iter().sum::<f64>() / means.len() as f64;
        let bv = means.iter().map(|v| (v - bm) * (v - bm)).sum::<f64>()
            / means.len().max(2) as f64;
        (m, bv / means.len() as f64)
    };
    let (ma, va) = mv(a);
    let (mb, vb) = mv(b);
    (ma - mb) / (va + vb).sqrt().max(1e-300)
}

/// Gelman-Rubin potential scale reduction factor R̂ over ≥2 chains
/// (split-free classic form). Values near 1 indicate convergence;
/// > 1.1 is the usual alarm threshold.
pub fn gelman_rubin(chains: &[Vec<f64>]) -> f64 {
    let m = chains.len();
    assert!(m >= 2, "R-hat needs at least two chains");
    let n = chains.iter().map(|c| c.len()).min().expect("chains");
    assert!(n >= 4, "chains too short for R-hat");
    let chains: Vec<&[f64]> = chains.iter().map(|c| &c[c.len() - n..]).collect();
    let means: Vec<f64> = chains
        .iter()
        .map(|c| c.iter().sum::<f64>() / n as f64)
        .collect();
    let grand = means.iter().sum::<f64>() / m as f64;
    // between-chain variance
    let b = n as f64 / (m as f64 - 1.0)
        * means.iter().map(|mu| (mu - grand) * (mu - grand)).sum::<f64>();
    // within-chain variance
    let w = chains
        .iter()
        .zip(&means)
        .map(|(c, mu)| {
            c.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / (n as f64 - 1.0)
        })
        .sum::<f64>()
        / m as f64;
    if w == 0.0 {
        return 1.0;
    }
    let var_plus = (n as f64 - 1.0) / n as f64 * w + b / n as f64;
    (var_plus / w).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Dist, Rng};

    fn iid(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn ar1(seed: u64, n: usize, rho: f64) -> Vec<f64> {
        let mut rng = Rng::seed_from(seed);
        let mut x = 0.0;
        (0..n)
            .map(|_| {
                x = rho * x + (1.0 - rho * rho).sqrt() * rng.normal();
                x
            })
            .collect()
    }

    #[test]
    fn acf_lag0_is_one_and_iid_decays() {
        let v = iid(1, 5000);
        let acf = autocorrelation(&v, 10);
        assert!((acf[0] - 1.0).abs() < 1e-12);
        for lag in 1..=10 {
            assert!(acf[lag].abs() < 0.05, "lag {lag}: {}", acf[lag]);
        }
    }

    #[test]
    fn acf_matches_ar1_theory() {
        let rho: f64 = 0.8;
        let v = ar1(2, 50_000, rho);
        let acf = autocorrelation(&v, 5);
        for lag in 1..=5 {
            let expect = rho.powi(lag as i32);
            assert!(
                (acf[lag as usize] - expect).abs() < 0.05,
                "lag {lag}: {} vs {expect}",
                acf[lag as usize]
            );
        }
    }

    #[test]
    fn iat_iid_near_one_ar1_large() {
        assert!((integrated_autocorr_time(&iid(3, 10_000)) - 1.0).abs() < 0.3);
        let tau = integrated_autocorr_time(&ar1(4, 20_000, 0.9));
        // theory: (1+rho)/(1-rho) = 19
        assert!((10.0..30.0).contains(&tau), "{tau}");
    }

    #[test]
    fn geweke_flags_trend_not_stationary() {
        let stationary = iid(5, 4000);
        let z = geweke_z(&stationary, 0.1, 0.5);
        assert!(z.abs() < 3.0, "{z}");
        let trending: Vec<f64> = (0..4000).map(|i| i as f64 * 0.01).collect();
        let z = geweke_z(&trending, 0.1, 0.5);
        assert!(z.abs() > 5.0, "{z}");
    }

    #[test]
    fn geweke_short_and_constant_contracts() {
        // fewer than 20 samples cannot support the batch-means variance
        // estimate: the contract is NaN, not a spurious z-score
        assert!(geweke_z(&[], 0.1, 0.5).is_nan());
        assert!(geweke_z(&[1.0; 19], 0.1, 0.5).is_nan());
        // a constant chain has equal window means: exactly zero
        assert_eq!(geweke_z(&[3.5; 64], 0.1, 0.5), 0.0);
    }

    #[test]
    fn iat_constant_and_trend_contracts() {
        // constant chain: zero variance short-circuits the ACF to
        // lag0-only, so tau is exactly the iid value
        assert_eq!(integrated_autocorr_time(&[2.0; 100]), 1.0);
        // a deterministic trend is maximally correlated: tau grows with n
        let trend: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert!(integrated_autocorr_time(&trend) > 100.0);
        // degenerate inputs fall back to tau = 1
        assert_eq!(integrated_autocorr_time(&[]), 1.0);
        assert_eq!(integrated_autocorr_time(&[7.0]), 1.0);
    }

    #[test]
    fn acf_degenerate_lengths() {
        assert_eq!(autocorrelation(&[], 5), vec![1.0]);
        assert_eq!(autocorrelation(&[4.2], 5), vec![1.0]);
        // n = 2: max_lag clamps to 1 and acf(1) = -1/2 exactly
        let acf = autocorrelation(&[1.0, 2.0], 5);
        assert_eq!(acf.len(), 2);
        assert!((acf[0] - 1.0).abs() < 1e-12);
        assert!((acf[1] + 0.5).abs() < 1e-12);
    }

    #[test]
    fn rhat_near_one_for_same_target() {
        let chains = vec![iid(6, 3000), iid(7, 3000), iid(8, 3000)];
        let r = gelman_rubin(&chains);
        assert!(r < 1.05, "{r}");
    }

    #[test]
    fn rhat_large_for_disagreeing_chains() {
        let mut a = iid(9, 2000);
        let b: Vec<f64> = iid(10, 2000).iter().map(|v| v + 5.0).collect();
        let r = gelman_rubin(&[std::mem::take(&mut a), b]);
        assert!(r > 1.5, "{r}");
    }

    #[test]
    fn rhat_constant_chains() {
        assert_eq!(gelman_rubin(&[vec![1.0; 10], vec![1.0; 10]]), 1.0);
    }
}
