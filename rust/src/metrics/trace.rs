//! Trace recording (value vs iteration vs time) with CSV output, plus
//! summary statistics (mean / sd / effective sample size) over the
//! post-burn-in samples.

use std::io::Write;
use std::path::Path;

use crate::util::Json;
use crate::Result;

/// Per-node robustness counters collected by the async cluster
/// executor: how often a node stalled on the staleness bound, how much
/// virtual time it lost, how its ring traffic fared, and how stale the
/// `H` blocks it consumed actually were.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeStats {
    /// Logical node index `0..B`.
    pub node: usize,
    /// Iterations this node executed (including re-execution after
    /// rollback).
    pub iterations: u64,
    /// Times the node blocked because a needed block exceeded `tau`.
    pub stalls: u64,
    /// Virtual seconds spent blocked.
    pub stall_seconds: f64,
    /// Crash→restart cycles this node went through.
    pub recoveries: u64,
    /// Ring messages this node produced.
    pub msgs_sent: u64,
    /// Ring messages from this node the network dropped.
    pub msgs_dropped: u64,
    /// Retransmissions after timeouts.
    pub retries: u64,
    /// Largest staleness (iterations) the node ever proceeded with.
    pub max_staleness: u64,
    /// Mean staleness over the node's executed iterations.
    pub mean_staleness: f64,
}

impl NodeStats {
    /// Canonical `(column, value)` row shared by the CSV and JSONL
    /// writers, in CSV column order. Non-finite floats map to
    /// [`Json::Null`] so both formats degrade identically (an empty
    /// CSV cell, a JSON `null`).
    fn row(&self) -> [(&'static str, Json); 10] {
        fn float(x: f64) -> Json {
            if x.is_finite() {
                Json::num(x)
            } else {
                Json::Null
            }
        }
        [
            ("node", Json::num(self.node as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("stalls", Json::num(self.stalls as f64)),
            ("stall_seconds", float(self.stall_seconds)),
            ("recoveries", Json::num(self.recoveries as f64)),
            ("msgs_sent", Json::num(self.msgs_sent as f64)),
            ("msgs_dropped", Json::num(self.msgs_dropped as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("mean_staleness", float(self.mean_staleness)),
        ]
    }
}

/// A named series of (iteration, seconds, value) observations.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub name: String,
    pub iters: Vec<u64>,
    pub seconds: Vec<f64>,
    pub values: Vec<f64>,
    /// Per-node robustness counters (empty outside the async cluster
    /// executor).
    pub node_stats: Vec<NodeStats>,
}

impl Trace {
    pub fn new(name: impl Into<String>) -> Self {
        Trace { name: name.into(), ..Default::default() }
    }

    pub fn push(&mut self, iter: u64, seconds: f64, value: f64) {
        self.iters.push(iter);
        self.seconds.push(seconds);
        self.values.push(value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn last_value(&self) -> f64 {
        *self.values.last().expect("empty trace")
    }

    pub fn total_seconds(&self) -> f64 {
        self.seconds.last().copied().unwrap_or(0.0)
    }

    /// Mean of the values recorded strictly after `burn_iters`.
    pub fn mean_after(&self, burn_iters: u64) -> f64 {
        let vals: Vec<f64> = self
            .iters
            .iter()
            .zip(&self.values)
            .filter(|(&it, _)| it > burn_iters)
            .map(|(_, &v)| v)
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// First iteration at which the value reaches within `frac` of the
    /// final plateau (a simple burn-in/mixing-speed indicator).
    pub fn iters_to_reach(&self, target: f64, higher_is_better: bool) -> Option<u64> {
        self.iters
            .iter()
            .zip(&self.values)
            .find(|(_, &v)| if higher_is_better { v >= target } else { v <= target })
            .map(|(&it, _)| it)
    }

    /// Write `iter,seconds,value` CSV (with a header).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "iter,seconds,{}", self.name)?;
        for i in 0..self.len() {
            writeln!(f, "{},{},{}", self.iters[i], self.seconds[i], self.values[i])?;
        }
        Ok(())
    }

    /// Write the per-node robustness counters as CSV (one row per node,
    /// with a header). No-op columns are still written so downstream
    /// plotting stays schema-stable. Non-finite floats (possible only
    /// on a zero-iteration node's `mean_staleness`) become empty cells,
    /// mirroring the JSONL writer's `null` — both render from
    /// [`NodeStats::row`].
    pub fn write_node_stats_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let header: Vec<&str> =
            NodeStats::default().row().iter().map(|&(name, _)| name).collect();
        writeln!(f, "{}", header.join(","))?;
        for s in &self.node_stats {
            let cells: Vec<String> = s
                .row()
                .iter()
                .map(|(_, v)| match v {
                    Json::Null => String::new(),
                    other => other.to_string_compact(),
                })
                .collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(())
    }

    /// Write the per-node robustness counters as JSON Lines: one JSON
    /// object per node per line, so `BENCH_fault.json`-style tooling
    /// can consume them without CSV parsing.
    ///
    /// Schema (every field always present, one object per node; keys
    /// serialise alphabetically):
    ///
    /// ```json
    /// {"iterations":40,"max_staleness":2,"mean_staleness":0.5,
    ///  "msgs_dropped":2,"msgs_sent":39,"node":0,"recoveries":1,
    ///  "retries":2,"stall_seconds":0.25,"stalls":3}
    /// ```
    ///
    /// Integer fields are JSON integers; `stall_seconds` and
    /// `mean_staleness` are JSON numbers (`null` if non-finite, which
    /// can only happen on a zero-iteration node). Rows render from the
    /// same [`NodeStats::row`] helper as the CSV writer.
    pub fn write_node_stats_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for s in &self.node_stats {
            let obj = Json::obj(s.row().to_vec());
            writeln!(f, "{}", obj.to_string_compact())?;
        }
        Ok(())
    }
}

/// Write several traces side by side (outer join on iteration).
pub fn write_csv_multi(traces: &[&Trace], path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "iter")?;
    for t in traces {
        write!(f, ",{}_seconds,{}_value", t.name, t.name)?;
    }
    writeln!(f)?;
    let rows = traces.iter().map(|t| t.len()).max().unwrap_or(0);
    for r in 0..rows {
        let it = traces
            .iter()
            .find(|t| r < t.len())
            .map(|t| t.iters[r])
            .unwrap_or(r as u64);
        write!(f, "{it}")?;
        for t in traces {
            if r < t.len() {
                write!(f, ",{},{}", t.seconds[r], t.values[r])?;
            } else {
                write!(f, ",,")?;
            }
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Summary statistics of a scalar MCMC chain.
#[derive(Clone, Copy, Debug)]
pub struct SummaryStats {
    pub mean: f64,
    pub sd: f64,
    /// Effective sample size via initial-positive-sequence autocorrelation.
    pub ess: f64,
    pub n: usize,
}

impl SummaryStats {
    /// Compute over raw chain values.
    pub fn from_chain(values: &[f64]) -> Self {
        let n = values.len();
        if n == 0 {
            return SummaryStats { mean: f64::NAN, sd: f64::NAN, ess: 0.0, n };
        }
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        let sd = var.sqrt();
        if var == 0.0 || n < 4 {
            return SummaryStats { mean, sd, ess: n as f64, n };
        }
        // Geyer initial positive sequence on autocorrelations
        let max_lag = (n / 2).min(1000);
        let acf = |lag: usize| -> f64 {
            let mut s = 0.0;
            for i in 0..n - lag {
                s += (values[i] - mean) * (values[i + lag] - mean);
            }
            s / (n as f64 * var)
        };
        let mut tau = 1.0;
        let mut lag = 1;
        while lag + 1 < max_lag {
            let pair = acf(lag) + acf(lag + 1);
            if pair <= 0.0 {
                break;
            }
            tau += 2.0 * pair;
            lag += 2;
        }
        SummaryStats { mean, sd, ess: n as f64 / tau, n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Dist, Rng};

    #[test]
    fn trace_push_and_stats() {
        let mut t = Trace::new("ll");
        for i in 0..10u64 {
            t.push(i, i as f64 * 0.1, i as f64);
        }
        assert_eq!(t.len(), 10);
        assert_eq!(t.last_value(), 9.0);
        assert!((t.mean_after(4) - 7.0).abs() < 1e-12); // mean of 5..=9
        assert_eq!(t.iters_to_reach(5.0, true), Some(5));
        assert_eq!(t.iters_to_reach(100.0, true), None);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let path = dir.join("t.csv");
        let mut t = Trace::new("x");
        t.push(0, 0.0, 1.5);
        t.push(1, 0.5, 2.5);
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,seconds,x"));
        assert!(text.contains("1,0.5,2.5"));
    }

    #[test]
    fn node_stats_csv() {
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let path = dir.join("nodes.csv");
        let mut t = Trace::new("async");
        t.node_stats.push(NodeStats {
            node: 1,
            iterations: 40,
            stalls: 3,
            stall_seconds: 0.25,
            recoveries: 1,
            msgs_sent: 39,
            msgs_dropped: 2,
            retries: 2,
            max_staleness: 2,
            mean_staleness: 0.5,
        });
        t.write_node_stats_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("node,iterations,stalls"));
        assert!(text.contains("1,40,3,0.25,1,39,2,2,2,0.5"));
    }

    #[test]
    fn node_stats_csv_non_finite_is_empty_cell() {
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let path = dir.join("nodes_nan.csv");
        let mut t = Trace::new("async");
        t.node_stats.push(NodeStats {
            node: 2,
            mean_staleness: f64::NAN,
            ..NodeStats::default()
        });
        t.write_node_stats_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let row = text.lines().nth(1).unwrap();
        // same degradation as the JSONL null: the cell is empty, and
        // the row still has all 10 columns
        assert_eq!(row, "2,0,0,0,0,0,0,0,0,");
        assert_eq!(row.split(',').count(), 10);
    }

    #[test]
    fn node_stats_csv_and_jsonl_share_one_row_schema() {
        let stats = NodeStats {
            node: 3,
            iterations: 7,
            stalls: 1,
            stall_seconds: 1.5,
            recoveries: 0,
            msgs_sent: 6,
            msgs_dropped: 0,
            retries: 0,
            max_staleness: 1,
            mean_staleness: 0.25,
        };
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let csv_path = dir.join("row_schema.csv");
        let jsonl_path = dir.join("row_schema.jsonl");
        let mut t = Trace::new("async");
        t.node_stats.push(stats);
        t.write_node_stats_csv(&csv_path).unwrap();
        t.write_node_stats_jsonl(&jsonl_path).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        let jsonl = std::fs::read_to_string(&jsonl_path).unwrap();
        let header: Vec<&str> = csv.lines().next().unwrap().split(',').collect();
        let cells: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        let obj = crate::util::Json::parse(jsonl.lines().next().unwrap()).unwrap();
        // every CSV column exists as a JSONL field with the same
        // serialised value
        for (name, cell) in header.iter().zip(&cells) {
            let field = obj.field(name).unwrap();
            assert_eq!(&field.to_string_compact(), cell, "column {name}");
        }
    }

    #[test]
    fn node_stats_jsonl() {
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let path = dir.join("nodes.jsonl");
        let mut t = Trace::new("async");
        t.node_stats.push(NodeStats {
            node: 1,
            iterations: 40,
            stalls: 3,
            stall_seconds: 0.25,
            recoveries: 1,
            msgs_sent: 39,
            msgs_dropped: 2,
            retries: 2,
            max_staleness: 2,
            mean_staleness: 0.5,
        });
        t.node_stats.push(NodeStats {
            node: 2,
            iterations: 0,
            stalls: 0,
            stall_seconds: 0.0,
            recoveries: 0,
            msgs_sent: 0,
            msgs_dropped: 0,
            retries: 0,
            max_staleness: 0,
            mean_staleness: f64::NAN,
        });
        t.write_node_stats_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j0 = crate::util::Json::parse(lines[0]).unwrap();
        assert_eq!(j0.field("node").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j0.field("iterations").unwrap().as_u64().unwrap(), 40);
        assert!((j0.field("stall_seconds").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(j0.field("msgs_dropped").unwrap().as_u64().unwrap(), 2);
        // non-finite mean_staleness must serialise as null, not break the line
        let j1 = crate::util::Json::parse(lines[1]).unwrap();
        assert!(matches!(j1.field("mean_staleness").unwrap(), crate::util::Json::Null));
    }

    #[test]
    fn multi_csv_ragged() {
        let dir = std::env::temp_dir().join("psgld_trace_test");
        let path = dir.join("m.csv");
        let mut a = Trace::new("a");
        a.push(0, 0.0, 1.0);
        a.push(1, 1.0, 2.0);
        let mut b = Trace::new("b");
        b.push(0, 0.0, 9.0);
        write_csv_multi(&[&a, &b], &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 3);
        assert!(text.lines().nth(2).unwrap().ends_with(",,"));
    }

    #[test]
    fn ess_iid_close_to_n() {
        let mut rng = Rng::seed_from(1);
        let vals: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let s = SummaryStats::from_chain(&vals);
        assert!(s.ess > 1200.0, "iid ess {}", s.ess);
        assert!(s.mean.abs() < 0.1);
    }

    #[test]
    fn ess_correlated_much_smaller() {
        let mut rng = Rng::seed_from(2);
        let mut x = 0.0;
        let vals: Vec<f64> = (0..2000)
            .map(|_| {
                x = 0.99 * x + 0.1 * rng.normal();
                x
            })
            .collect();
        let s = SummaryStats::from_chain(&vals);
        assert!(s.ess < 300.0, "AR(0.99) ess {}", s.ess);
    }

    #[test]
    fn ess_constant_chain() {
        let s = SummaryStats::from_chain(&[2.0; 50]);
        assert_eq!(s.ess, 50.0);
        assert_eq!(s.sd, 0.0);
    }
}
