//! Multi-chain runner: run C independent chains of any sampler on OS
//! threads and assess convergence with the Gelman-Rubin R̂ over the
//! monitored statistic — the standard workflow the paper's "full
//! Bayesian inference" pitch implies but single-chain demos skip.

use crate::config::RunConfig;
use crate::metrics::diagnostics::gelman_rubin;
use crate::samplers::{run_sampler, FactorState, RunResult, Sampler};
use crate::util::parallel::WorkerPool;

/// Outcome of a multi-chain run.
pub struct MultiChainResult {
    /// Per-chain results, in chain order.
    pub chains: Vec<RunResult>,
    /// R̂ of the monitor over the post-burn-in trace segments.
    pub rhat: f64,
}

impl MultiChainResult {
    /// Pool post-burn-in monitor values across chains.
    pub fn pooled_values(&self, burn_in: u64) -> Vec<f64> {
        let mut all = Vec::new();
        for c in &self.chains {
            for (it, v) in c.trace.iters.iter().zip(&c.trace.values) {
                if *it > burn_in {
                    all.push(*v);
                }
            }
        }
        all
    }
}

/// Run `n_chains` chains built by `make_chain(chain_index)` in parallel
/// (each factory should vary the seed), monitoring with `monitor`.
pub fn run_chains<S, F, M>(
    n_chains: usize,
    threads: usize,
    run: &RunConfig,
    make_chain: F,
    monitor: M,
) -> MultiChainResult
where
    S: Sampler + Send,
    F: Fn(usize) -> S + Sync,
    M: Fn(&FactorState) -> f64 + Sync,
{
    let idxs: Vec<usize> = (0..n_chains).collect();
    let mut pool = WorkerPool::new(threads.max(1).min(n_chains.max(1)));
    let chains = pool.map(idxs, |_, c| {
        // Attribute this chain's monitor stream to its own index so the
        // health monitor can compute an across-chain split-Rhat.
        crate::monitor::with_chain(c, || {
            let mut sampler = make_chain(c);
            run_sampler(&mut sampler, run, |s| monitor(s))
        })
    });
    let post: Vec<Vec<f64>> = chains
        .iter()
        .map(|r| {
            r.trace
                .iters
                .iter()
                .zip(&r.trace.values)
                .filter(|(&it, _)| it > run.burn_in)
                .map(|(_, &v)| v)
                .collect()
        })
        .collect();
    let rhat = if n_chains >= 2 && post.iter().all(|c| c.len() >= 4) {
        gelman_rubin(&post)
    } else {
        f64::NAN
    };
    MultiChainResult { chains, rhat }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, StepSchedule};
    use crate::data::synth;
    use crate::model::NmfModel;
    use crate::samplers::Psgld;

    #[test]
    fn chains_converge_to_common_posterior() {
        // monitor the reconstruction mass — a well-identified scalar
        // (loglik mixes slowly under the decaying-step schedule)
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(24, 24, &model, 77);
        let run = RunConfig::quick(4000)
            .with_step(StepSchedule::Polynomial { a: 0.004, b: 0.51 })
            .with_monitor_every(10);
        let res = run_chains(
            3,
            3,
            &run,
            |c| Psgld::new(&data.v, &model, 3, run.clone(), 1000 + c as u64),
            |s| {
                s.reconstruct().as_slice().iter().map(|&x| x as f64).sum::<f64>()
            },
        );
        assert_eq!(res.chains.len(), 3);
        assert!(res.rhat.is_finite());
        assert!(res.rhat < 1.25, "chains disagree: rhat {}", res.rhat);
        assert!(!res.pooled_values(run.burn_in).is_empty());
    }

    #[test]
    fn single_chain_has_nan_rhat() {
        let model = NmfModel::poisson(2);
        let data = synth::poisson_nmf(12, 12, &model, 78);
        let run = RunConfig::quick(50).with_monitor_every(5);
        let res = run_chains(
            1,
            1,
            &run,
            |c| Psgld::new(&data.v, &model, 2, run.clone(), c as u64),
            |s| model.loglik_dense(&s.w, &s.h(), &data.v),
        );
        assert!(res.rhat.is_nan());
    }
}
