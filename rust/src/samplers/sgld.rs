//! Vanilla SGLD (Welling & Teh 2011) with the *with-replacement*
//! arbitrary sub-sampling the paper uses as a baseline (§4.2.1,
//! `|Ω| = IJ/32`): at each iteration draw `|Ω|` entries uniformly at
//! random, scale the noisy gradient by `N/|Ω|`, update the full factor
//! matrices. The scattered access pattern is exactly why the paper's
//! Fig. 2 shows SGLD gaining little wall-clock over LD — we reproduce
//! that behaviour faithfully rather than optimising it away.

use crate::config::StepSchedule;
use crate::kernels::sgld_apply;
use crate::linalg::Mat;
use crate::model::tweedie::{grad_error, MU_EPS};
use crate::model::NmfModel;
use crate::rng::Rng;
use crate::samplers::{FactorState, Sampler};

/// With-replacement subsampling SGLD over a dense observed matrix.
pub struct Sgld {
    v: Mat,
    model: NmfModel,
    state: FactorState,
    step: StepSchedule,
    /// Sub-sample size |Ω| per iteration.
    pub omega: usize,
    rng: Rng,
    // gradient accumulators reused across iterations (no per-step alloc)
    gw: Mat,
    ght: Mat,
}

impl Sgld {
    pub fn new(
        v: &Mat,
        model: &NmfModel,
        omega: usize,
        step: StepSchedule,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::derive(seed, &[0x56_1d]);
        let state = FactorState::from_prior(model, v.rows(), v.cols(), &mut rng);
        let (i, j, k) = state.shape();
        Sgld {
            v: v.clone(),
            model: model.clone(),
            state,
            step,
            omega: omega.max(1),
            rng,
            gw: Mat::zeros(i, k),
            ght: Mat::zeros(j, k),
        }
    }

    pub fn with_state(mut self, state: FactorState) -> Self {
        self.state = state;
        self
    }
}

impl Sampler for Sgld {
    fn step(&mut self, t: u64) {
        let eps = self.step.eps(t) as f32;
        let (i, j, k) = self.state.shape();
        let n = (i * j) as f32;
        let scale = n / self.omega as f32;

        self.gw.as_mut_slice().fill(0.0);
        self.ght.as_mut_slice().fill(0.0);

        for _ in 0..self.omega {
            // with-replacement uniform entry (the paper's Ω^(t) draw)
            let ri = self.rng.next_below(i as u64) as usize;
            let rj = self.rng.next_below(j as u64) as usize;
            let wrow = self.state.w.row(ri);
            let htrow = self.state.ht.row(rj);
            let mut mu = MU_EPS;
            for kk in 0..k {
                mu += wrow[kk].abs() * htrow[kk].abs();
            }
            let e = grad_error(self.v.get(ri, rj), mu, self.model.beta, self.model.phi);
            let gwrow = self.gw.row_mut(ri);
            for kk in 0..k {
                let s = if wrow[kk] == 0.0 { 0.0 } else { wrow[kk].signum() };
                gwrow[kk] += e * s * htrow[kk].abs();
            }
            let ghtrow = self.ght.row_mut(rj);
            for kk in 0..k {
                let s = if htrow[kk] == 0.0 { 0.0 } else { htrow[kk].signum() };
                ghtrow[kk] += e * s * wrow[kk].abs();
            }
        }

        sgld_apply(
            &mut self.state.w,
            &self.gw,
            eps,
            scale,
            self.model.lam_w,
            self.model.mirror,
            &mut self.rng,
        );
        sgld_apply(
            &mut self.state.ht,
            &self.ght,
            eps,
            scale,
            self.model.lam_h,
            self.model.mirror,
            &mut self.rng,
        );
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "sgld"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::synth;
    use crate::samplers::run_sampler;

    #[test]
    fn sgld_improves_loglik() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(32, 32, &model, 5);
        let omega = 32 * 32 / 8;
        let mut s = Sgld::new(
            &data.v,
            &model,
            omega,
            StepSchedule::Polynomial { a: 1e-3, b: 0.51 },
            9,
        );
        let run = RunConfig::quick(300);
        let res = run_sampler(&mut s, &run, |st| model.loglik_dense(&st.w, &st.h(), &data.v));
        assert!(res.trace.last_value() > res.trace.values[0]);
    }

    #[test]
    fn subsample_gradient_unbiasedness() {
        // E over subsamples of the scaled stochastic gradient ≈ full
        // gradient (Condition on which SGLD validity rests).
        use crate::kernels::dense_block_grads;
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(12, 12, &model, 6);
        let mut rng = Rng::seed_from(10);
        let state = FactorState::from_prior(&model, 12, 12, &mut rng);
        let full = dense_block_grads(&state.w, &state.ht, &data.v, 1.0, 1.0);

        let omega = 24;
        let reps = 4000;
        let mut acc = Mat::zeros(12, 3);
        let n = 144.0f32;
        for _ in 0..reps {
            // one stochastic-gradient estimate for W
            let mut g = Mat::zeros(12, 3);
            for _ in 0..omega {
                let ri = rng.next_below(12) as usize;
                let rj = rng.next_below(12) as usize;
                let wrow = state.w.row(ri);
                let htrow = state.ht.row(rj);
                let mut mu = MU_EPS;
                for kk in 0..3 {
                    mu += wrow[kk].abs() * htrow[kk].abs();
                }
                let e = grad_error(data.v.get(ri, rj), mu, 1.0, 1.0);
                for kk in 0..3 {
                    g.as_mut_slice()[ri * 3 + kk] += e * htrow[kk].abs();
                }
            }
            acc.axpy(n / omega as f32 / reps as f32, &g).unwrap();
        }
        // compare mean estimate to the full gradient, entrywise-ish
        let denom = full.gw.as_slice().iter().map(|&x| x.abs()).sum::<f32>() / 36.0;
        let err = acc.frob_dist(&full.gw) / 6.0; // / sqrt(#entries)
        assert!(err < 0.2 * denom.max(1.0) as f64, "err {err} denom {denom}");
    }
}
