//! PSGLD — the paper's contribution (§3): grid-partition `V` into `B×B`
//! blocks; at each iteration pick a part (B mutually disjoint blocks)
//! and run the B block-SGLD updates **in parallel**, since the factor
//! blocks a part touches are conditionally independent.
//!
//! This is the shared-memory implementation (the paper's CUDA analogue):
//! the factor matrices are updated in place through disjoint stripe
//! slices driven by a persistent [`WorkerPool`] — threads are created
//! once per sampler and parked between iterations, and the steady-state
//! `step` performs **zero heap allocations** (per-block gradient buffers
//! and per-worker kernel scratch are all reused). The distributed
//! implementation (ring of Fig. 4) lives in [`crate::cluster`]; the
//! batched-HLO implementation in [`crate::coordinator`].
//!
//! Determinism contract: every per-block RNG stream is derived from
//! `(seed, t, block)` — never from the worker slot — so the chain is
//! bitwise identical across thread counts and [`ExecMode`]s.

use crate::config::RunConfig;
use crate::data::sparse::{BlockedSparse, Csr};
use crate::kernels::{
    grads_dense_tiled, grads_sparse_core, nonneg_hint, sgd_apply_core, sgld_apply_core,
};
use crate::linalg::Mat;
use crate::metrics;
use crate::model::NmfModel;
use crate::obs::{counter_add, Counter, Phase, Span};
use crate::partition::{GridPartition, Part, PartScheduler};
use crate::rng::Rng;
use crate::samplers::{run_sampler, sparse_block_langevin, FactorState, RunResult, Sampler};
use crate::util::parallel::{
    default_threads, par_for_each_mut, ScratchArena, SendPtr, WorkerPool,
};
use crate::Result;

/// How [`Psgld::step`] executes the B disjoint block updates of a part.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent worker pool (default): threads created once, parked
    /// between steps, per-worker scratch arenas — zero steady-state
    /// allocation.
    #[default]
    Pool,
    /// Spawn-per-step baseline (the pre-pool regime): fresh OS threads
    /// and fresh kernel scratch every step. Numerically identical to
    /// `Pool`; kept as the before/after reference for the fig6 bench.
    Spawn,
    /// Single-threaded execution on the caller thread (no
    /// synchronisation at all; the determinism reference).
    Inline,
}

/// The observed data, pre-decomposed into grid blocks.
enum DataBlocks {
    /// Dense: block `(bi, bj)` at `bi * B + bj` (row-major `m × n`).
    Dense(Vec<Mat>),
    /// Sparse: block-local CSR per block.
    Sparse(BlockedSparse),
}

/// Shared-memory parallel SGLD over matrix-factorisation blocks.
pub struct Psgld {
    model: NmfModel,
    grid: GridPartition,
    data: DataBlocks,
    state: FactorState,
    scheduler: PartScheduler,
    run_cfg: RunConfig,
    seed: u64,
    threads: usize,
    /// When false, skip the Langevin noise — this turns PSGLD into the
    /// DSGD optimisation baseline (used by [`super::Dsgd`]).
    pub langevin: bool,
    /// Per-block gradient accumulators, reused across iterations.
    scratch: Vec<(Vec<f32>, Vec<f32>)>,
    /// Persistent workers (with per-worker kernel scratch arenas).
    pool: WorkerPool,
    /// Execution strategy for the per-part block fan-out.
    exec: ExecMode,
    /// Reusable part buffer (overwritten in place each step).
    part: Part,
    /// Sparse V kept for monitors.
    sparse_v: Option<Csr>,
}

impl Psgld {
    /// Dense-data PSGLD with a `b × b` grid.
    pub fn new(v: &Mat, model: &NmfModel, b: usize, run: RunConfig, seed: u64) -> Self {
        let grid = GridPartition::new(v.rows(), v.cols(), b).expect("valid B");
        let blocks: Vec<Mat> = (0..b)
            .flat_map(|bi| {
                let grid = &grid;
                (0..b).map(move |bj| {
                    let (r, c) = (grid.row_range(bi), grid.col_range(bj));
                    v.slice_block(r.start, r.end, c.start, c.end)
                })
            })
            .collect();
        Self::build(model, grid, DataBlocks::Dense(blocks), run, seed, None)
    }

    /// Sparse-data PSGLD (observed entries only; `N` = nnz).
    pub fn new_sparse(
        v: &Csr,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> Result<Self> {
        let blocked = BlockedSparse::from_csr(v, b)?;
        let grid = blocked.grid().clone();
        Ok(Self::build(
            model,
            grid,
            DataBlocks::Sparse(blocked),
            run,
            seed,
            Some(v.clone()),
        ))
    }

    fn build(
        model: &NmfModel,
        grid: GridPartition,
        data: DataBlocks,
        run: RunConfig,
        seed: u64,
        sparse_v: Option<Csr>,
    ) -> Self {
        let mut rng = Rng::derive(seed, &[0x9516_1d]);
        let state = FactorState::from_prior(model, grid.rows(), grid.cols(), &mut rng);
        let b = grid.b();
        let k = model.k;
        let scratch = (0..b)
            .map(|bi| {
                let max_n = (0..b)
                    .map(|bj| grid.col_range(bj).len())
                    .max()
                    .unwrap_or(0);
                (
                    vec![0f32; grid.row_range(bi).len() * k],
                    vec![0f32; max_n * k],
                )
            })
            .collect();
        let threads = default_threads().min(b);
        Psgld {
            model: model.clone(),
            scheduler: PartScheduler::new(run.schedule, b),
            run_cfg: run,
            grid,
            data,
            state,
            seed,
            threads,
            langevin: true,
            scratch,
            pool: WorkerPool::new(threads),
            exec: ExecMode::Pool,
            part: Part::identity(b),
            sparse_v,
        }
    }

    /// Override the worker-thread bound (defaults to
    /// `min(B, default_threads())`). Rebuilds the persistent pool at the
    /// new width.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self.pool = WorkerPool::new(self.threads.min(self.grid.b()));
        self
    }

    /// Select how the per-part block fan-out executes (pool by default;
    /// `Spawn` reproduces the pre-pool thread-per-step regime, `Inline`
    /// runs single-threaded). All modes are bitwise identical.
    pub fn with_exec_mode(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Replace the initial state.
    pub fn with_state(mut self, state: FactorState) -> Self {
        self.state = state;
        self
    }

    pub fn grid(&self) -> &GridPartition {
        &self.grid
    }

    /// Convenience: run with the configured `RunConfig` and the default
    /// log-likelihood monitor; returns the full result.
    pub fn run(&mut self, run: &RunConfig) -> RunResult {
        crate::monitor::set_context(self.name());
        let model = self.model.clone();
        let sparse = self.sparse_v.clone();
        match sparse {
            Some(csr) => run_sampler(self, run, move |s| {
                metrics::loglik_sparse(&s.w, &s.h(), &csr, model.beta, model.phi)
            }),
            None => {
                let dense = self.dense_v();
                run_sampler(self, run, move |s| {
                    model.loglik_dense(&s.w, &s.h(), &dense)
                })
            }
        }
    }

    /// Reassemble the dense V from its blocks (monitor path only).
    fn dense_v(&self) -> Mat {
        match &self.data {
            DataBlocks::Dense(blocks) => {
                let b = self.grid.b();
                let mut v = Mat::zeros(self.grid.rows(), self.grid.cols());
                for bi in 0..b {
                    for bj in 0..b {
                        let r = self.grid.row_range(bi);
                        let c = self.grid.col_range(bj);
                        v.write_block(r.start, c.start, &blocks[bi * b + bj]);
                    }
                }
                v
            }
            DataBlocks::Sparse(_) => unreachable!("dense_v on sparse data"),
        }
    }

}

impl Sampler for Psgld {
    fn step(&mut self, t: u64) {
        // Dropped last: the Step span spans the whole iteration.
        let _step_span = Span::enter(Phase::Step, "step");
        counter_add(Counter::Steps, 1);
        let schedule_span = Span::enter(Phase::Schedule, "schedule_part");
        let b = self.grid.b();
        let k = self.model.k;
        let mut rng = Rng::derive(self.seed, &[t, 0xcafe]);
        self.scheduler.next_part_into(&mut rng, &mut self.part);
        let eps = self.run_cfg.step.eps(t) as f32;
        let scale = match &self.data {
            DataBlocks::Dense(_) => self.grid.scale_dense(&self.part),
            DataBlocks::Sparse(bs) => bs.scale(&self.part),
        };
        // The sparse kernel's nonneg fast path is decided once per part
        // from the pre-step state (the mirror flag settles it for free),
        // not rescanned per block. The cluster simulator mirrors this
        // computation exactly — keep the two in sync.
        let sparse_nonneg = match &self.data {
            DataBlocks::Dense(_) => self.model.mirror,
            DataBlocks::Sparse(bs) => nonneg_hint(
                self.model.mirror,
                self.state.w.as_slice(),
                self.state.ht.as_slice(),
                bs.nnz(),
            ),
        };
        drop(schedule_span);

        // Base pointers for the in-place stripe updates. The closure
        // below re-derives each block's W row-stripe and Ht col-stripe
        // from these; no per-step slice or task vectors are built.
        let w_ptr = SendPtr::new(self.state.w.as_mut_slice().as_mut_ptr());
        let ht_ptr = SendPtr::new(self.state.ht.as_mut_slice().as_mut_ptr());
        let scratch_ptr = SendPtr::new(self.scratch.as_mut_ptr());

        let grid = &self.grid;
        let data = &self.data;
        let model = &self.model;
        let part = &self.part;
        let seed = self.seed;
        let langevin = self.langevin;

        let body = move |arena: &mut ScratchArena, bi: usize| {
            let bj = part.perm[bi];
            let rows = grid.row_range(bi);
            let cols = grid.col_range(bj);
            let (m, n) = (rows.len(), cols.len());
            // SAFETY: row stripes are disjoint across bi; column stripes
            // are disjoint across bj = perm[bi] because perm is a
            // bijection; scratch[bi] is touched by exactly one task.
            // Stripes are whole-row (resp. whole-col) ranges of the
            // row-major buffers, hence contiguous.
            let w = unsafe {
                std::slice::from_raw_parts_mut(w_ptr.get().add(rows.start * k), m * k)
            };
            let ht = unsafe {
                std::slice::from_raw_parts_mut(ht_ptr.get().add(cols.start * k), n * k)
            };
            let sb = unsafe { &mut *scratch_ptr.get().add(bi) };
            let gw = &mut sb.0[..m * k];
            let ght = &mut sb.1[..n * k];
            if langevin {
                if let DataBlocks::Sparse(bs) = data {
                    // The sparse Langevin body is shared with both
                    // cluster simulators; see samplers/block_step.rs.
                    sparse_block_langevin(
                        w, ht, k, bs.block(bi, bj), model, sparse_nonneg,
                        eps, scale, seed, t, bi as u64, gw, ght, arena,
                    );
                    return;
                }
            }
            counter_add(Counter::Blocks, 1);
            {
                let _kernel_span = Span::enter(Phase::Kernel, "grads_block");
                gw.fill(0.0);
                ght.fill(0.0);
                match data {
                    DataBlocks::Dense(blocks) => {
                        let _ = grads_dense_tiled(
                            w, m, ht, n, k,
                            blocks[bi * b + bj].as_slice(),
                            model.beta, model.phi, model.mirror,
                            gw, ght, arena,
                        );
                    }
                    DataBlocks::Sparse(bs) => {
                        let _ = grads_sparse_core(
                            w, ht, k, bs.block(bi, bj),
                            model.beta, model.phi, sparse_nonneg,
                            gw, ght,
                        );
                    }
                }
            }
            let _noise_span = Span::enter(Phase::Noise, "apply_block");
            // Per-block stream keyed by (seed, t, bi) — independent of
            // which worker slot runs the block.
            let mut brng = Rng::derive(seed, &[t, bi as u64]);
            if langevin {
                sgld_apply_core(w, gw, eps, scale, model.lam_w, model.mirror, &mut brng, arena);
                sgld_apply_core(ht, ght, eps, scale, model.lam_h, model.mirror, &mut brng, arena);
            } else {
                sgd_apply_core(w, gw, eps, scale, model.lam_w, model.mirror);
                sgd_apply_core(ht, ght, eps, scale, model.lam_h, model.mirror);
            }
        };

        match self.exec {
            ExecMode::Pool => self.pool.for_each_index(b, body),
            ExecMode::Inline => self.pool.for_each_index_inline(b, body),
            ExecMode::Spawn => {
                // Pre-pool regime: per-step index vector, per-task
                // kernel scratch, fresh OS threads via par_for_each_mut.
                let mut idxs: Vec<usize> = (0..b).collect();
                par_for_each_mut(&mut idxs, self.threads, |_, bi| {
                    let mut arena = ScratchArena::new();
                    body(&mut arena, *bi);
                });
            }
        }
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        if self.langevin {
            "psgld"
        } else {
            "dsgd"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, StepSchedule};
    use crate::data::synth;

    fn quick_run(b: usize, threads: usize, seed: u64) -> (f64, f64, FactorState) {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(32, 32, &model, 11);
        let run = RunConfig::quick(200)
            .with_step(StepSchedule::Polynomial { a: 0.005, b: 0.51 });
        let mut s = Psgld::new(&data.v, &model, b, run.clone(), seed).with_threads(threads);
        let res = s.run(&run);
        (
            res.trace.values[0],
            res.trace.last_value(),
            s.state().clone(),
        )
    }

    #[test]
    fn psgld_improves_loglik() {
        let (first, last, _) = quick_run(4, 1, 13);
        assert!(last > first, "{first} -> {last}");
    }

    #[test]
    fn thread_count_does_not_change_the_chain() {
        // per-block RNG streams are derived from (seed, t, block), so
        // the chain is bitwise identical regardless of thread count
        let (_, last1, s1) = quick_run(4, 1, 17);
        let (_, last4, s4) = quick_run(4, 4, 17);
        let (_, lastd, sd) = quick_run(4, default_threads(), 17);
        assert_eq!(last1, last4);
        assert_eq!(s1.w, s4.w);
        assert_eq!(s1.ht, s4.ht);
        assert_eq!(last1, lastd);
        assert_eq!(s1.w, sd.w);
        assert_eq!(s1.ht, sd.ht);
    }

    fn quick_run_sparse(threads: usize, exec: ExecMode, seed: u64) -> FactorState {
        use crate::data::movielens;
        let csr = movielens::movielens_like_dims(40, 50, 600, 4, 9);
        let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
        let run = RunConfig::quick(60)
            .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        let mut s = Psgld::new_sparse(&csr, &model, 4, run, seed)
            .unwrap()
            .with_threads(threads)
            .with_exec_mode(exec);
        for t in 1..=60 {
            s.step(t);
        }
        s.state().clone()
    }

    #[test]
    fn sparse_thread_count_does_not_change_the_chain() {
        // same contract on the sparse path: 1, 2 and default_threads()
        // workers produce a bitwise-identical FactorState
        let s1 = quick_run_sparse(1, ExecMode::Pool, 23);
        let s2 = quick_run_sparse(2, ExecMode::Pool, 23);
        let sd = quick_run_sparse(default_threads(), ExecMode::Pool, 23);
        assert_eq!(s1.w, s2.w);
        assert_eq!(s1.ht, s2.ht);
        assert_eq!(s1.w, sd.w);
        assert_eq!(s1.ht, sd.ht);
    }

    #[test]
    fn exec_modes_are_bitwise_identical() {
        // pool vs inline vs the spawn-per-step baseline: the chain must
        // not depend on how the block fan-out is executed
        let pool = quick_run_sparse(4, ExecMode::Pool, 29);
        let inline = quick_run_sparse(4, ExecMode::Inline, 29);
        let spawn = quick_run_sparse(4, ExecMode::Spawn, 29);
        assert_eq!(pool.w, inline.w);
        assert_eq!(pool.ht, inline.ht);
        assert_eq!(pool.w, spawn.w);
        assert_eq!(pool.ht, spawn.ht);

        // dense path too
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(24, 24, &model, 31);
        let run = RunConfig::quick(40);
        let mut states = Vec::new();
        for exec in [ExecMode::Pool, ExecMode::Inline, ExecMode::Spawn] {
            let mut s = Psgld::new(&data.v, &model, 3, run.clone(), 7)
                .with_threads(3)
                .with_exec_mode(exec);
            for t in 1..=40 {
                s.step(t);
            }
            states.push(s.state().clone());
        }
        assert_eq!(states[0].w, states[1].w);
        assert_eq!(states[0].ht, states[1].ht);
        assert_eq!(states[0].w, states[2].w);
        assert_eq!(states[0].ht, states[2].ht);
    }

    #[test]
    fn mirroring_keeps_nonnegative() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(24, 24, &model, 12);
        let run = RunConfig::quick(50);
        let mut s = Psgld::new(&data.v, &model, 3, run.clone(), 1);
        for t in 1..=50 {
            s.step(t);
        }
        assert!(s.state().w.as_slice().iter().all(|&x| x >= 0.0));
        assert!(s.state().ht.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sparse_psgld_runs_and_improves_rmse() {
        use crate::data::movielens;
        use crate::metrics::rmse_sparse;
        let csr = movielens::movielens_like_dims(60, 80, 900, 4, 3);
        let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
        let run = RunConfig::quick(300)
            .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        let mut s = Psgld::new_sparse(&csr, &model, 4, run.clone(), 5).unwrap();
        let rmse0 = rmse_sparse(&s.state().w, &s.state().h(), &csr);
        for t in 1..=300 {
            s.step(t);
        }
        let rmse1 = rmse_sparse(&s.state().w, &s.state().h(), &csr);
        assert!(rmse1 < rmse0, "{rmse0} -> {rmse1}");
    }

    #[test]
    fn uneven_grid_supported() {
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(25, 31, &model, 14);
        let run = RunConfig::quick(30);
        let mut s = Psgld::new(&data.v, &model, 3, run.clone(), 2);
        for t in 1..=30 {
            s.step(t);
        }
        assert!(s
            .state()
            .w
            .as_slice()
            .iter()
            .all(|x| x.is_finite()));
    }

    #[test]
    fn dense_v_roundtrip() {
        let model = NmfModel::poisson(2);
        let data = synth::poisson_nmf(12, 12, &model, 15);
        let s = Psgld::new(&data.v, &model, 3, RunConfig::quick(10), 3);
        assert_eq!(s.dense_v(), data.v);
    }
}
