//! PSGLD — the paper's contribution (§3): grid-partition `V` into `B×B`
//! blocks; at each iteration pick a part (B mutually disjoint blocks)
//! and run the B block-SGLD updates **in parallel**, since the factor
//! blocks a part touches are conditionally independent.
//!
//! This is the shared-memory implementation (the paper's CUDA analogue):
//! the factor matrices are updated in place through disjoint stripe
//! slices, one OS thread per block (bounded by `threads`). The
//! distributed implementation (ring of Fig. 4) lives in
//! [`crate::cluster`]; the batched-HLO implementation in
//! [`crate::coordinator`].

use crate::config::RunConfig;
use crate::data::sparse::{BlockedSparse, Csr};
use crate::kernels::{grads_dense_core, grads_sparse_core, sgd_apply_core, sgld_apply_core};
use crate::linalg::Mat;
use crate::metrics;
use crate::model::NmfModel;
use crate::partition::{GridPartition, PartScheduler};
use crate::rng::Rng;
use crate::samplers::{run_sampler, FactorState, RunResult, Sampler};
use crate::util::parallel::{default_threads, par_for_each_mut};
use crate::Result;

/// The observed data, pre-decomposed into grid blocks.
enum DataBlocks {
    /// Dense: block `(bi, bj)` at `bi * B + bj` (row-major `m × n`).
    Dense(Vec<Mat>),
    /// Sparse: local-index COO per block.
    Sparse(BlockedSparse),
}

/// Shared-memory parallel SGLD over matrix-factorisation blocks.
pub struct Psgld {
    model: NmfModel,
    grid: GridPartition,
    data: DataBlocks,
    state: FactorState,
    scheduler: PartScheduler,
    run_cfg: RunConfig,
    seed: u64,
    threads: usize,
    /// When false, skip the Langevin noise — this turns PSGLD into the
    /// DSGD optimisation baseline (used by [`super::Dsgd`]).
    pub langevin: bool,
    /// Per-block gradient scratch, reused across iterations.
    scratch: Vec<(Vec<f32>, Vec<f32>)>,
    /// Sparse V kept for monitors.
    sparse_v: Option<Csr>,
}

impl Psgld {
    /// Dense-data PSGLD with a `b × b` grid.
    pub fn new(v: &Mat, model: &NmfModel, b: usize, run: RunConfig, seed: u64) -> Self {
        let grid = GridPartition::new(v.rows(), v.cols(), b).expect("valid B");
        let blocks: Vec<Mat> = (0..b)
            .flat_map(|bi| {
                let grid = &grid;
                (0..b).map(move |bj| {
                    let (r, c) = (grid.row_range(bi), grid.col_range(bj));
                    v.slice_block(r.start, r.end, c.start, c.end)
                })
            })
            .collect();
        Self::build(model, grid, DataBlocks::Dense(blocks), run, seed, None)
    }

    /// Sparse-data PSGLD (observed entries only; `N` = nnz).
    pub fn new_sparse(
        v: &Csr,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> Result<Self> {
        let blocked = BlockedSparse::from_csr(v, b)?;
        let grid = blocked.grid().clone();
        Ok(Self::build(
            model,
            grid,
            DataBlocks::Sparse(blocked),
            run,
            seed,
            Some(v.clone()),
        ))
    }

    fn build(
        model: &NmfModel,
        grid: GridPartition,
        data: DataBlocks,
        run: RunConfig,
        seed: u64,
        sparse_v: Option<Csr>,
    ) -> Self {
        let mut rng = Rng::derive(seed, &[0x9516_1d]);
        let state = FactorState::from_prior(model, grid.rows(), grid.cols(), &mut rng);
        let b = grid.b();
        let k = model.k;
        let scratch = (0..b)
            .map(|bi| {
                let max_n = (0..b)
                    .map(|bj| grid.col_range(bj).len())
                    .max()
                    .unwrap_or(0);
                (
                    vec![0f32; grid.row_range(bi).len() * k],
                    vec![0f32; max_n * k],
                )
            })
            .collect();
        Psgld {
            model: model.clone(),
            scheduler: PartScheduler::new(run.schedule, b),
            run_cfg: run,
            grid,
            data,
            state,
            seed,
            threads: default_threads().min(b),
            langevin: true,
            scratch,
            sparse_v,
        }
    }

    /// Override the worker-thread bound (defaults to
    /// `min(B, available_parallelism)`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Replace the initial state.
    pub fn with_state(mut self, state: FactorState) -> Self {
        self.state = state;
        self
    }

    pub fn grid(&self) -> &GridPartition {
        &self.grid
    }

    /// Convenience: run with the configured `RunConfig` and the default
    /// log-likelihood monitor; returns the full result.
    pub fn run(&mut self, run: &RunConfig) -> RunResult {
        let model = self.model.clone();
        let sparse = self.sparse_v.clone();
        match sparse {
            Some(csr) => run_sampler(self, run, move |s| {
                metrics::loglik_sparse(&s.w, &s.h(), &csr, model.beta, model.phi)
            }),
            None => {
                let dense = self.dense_v();
                run_sampler(self, run, move |s| {
                    model.loglik_dense(&s.w, &s.h(), &dense)
                })
            }
        }
    }

    /// Reassemble the dense V from its blocks (monitor path only).
    fn dense_v(&self) -> Mat {
        match &self.data {
            DataBlocks::Dense(blocks) => {
                let b = self.grid.b();
                let mut v = Mat::zeros(self.grid.rows(), self.grid.cols());
                for bi in 0..b {
                    for bj in 0..b {
                        let r = self.grid.row_range(bi);
                        let c = self.grid.col_range(bj);
                        v.write_block(r.start, c.start, &blocks[bi * b + bj]);
                    }
                }
                v
            }
            DataBlocks::Sparse(_) => unreachable!("dense_v on sparse data"),
        }
    }

    /// Split a row-major matrix buffer into per-stripe mutable slices
    /// (stripes are whole-row ranges, so slices are contiguous).
    fn stripe_slices<'a>(
        data: &'a mut [f32],
        bounds: impl Iterator<Item = usize>,
        k: usize,
    ) -> Vec<&'a mut [f32]> {
        let mut out = Vec::new();
        let mut rest = data;
        let mut prev = 0usize;
        for bound in bounds {
            let (head, tail) = rest.split_at_mut((bound - prev) * k);
            out.push(head);
            rest = tail;
            prev = bound;
        }
        out
    }
}

/// Per-block work item handed to the worker threads.
struct BlockTask<'a> {
    w: &'a mut [f32],
    m: usize,
    ht: &'a mut [f32],
    n: usize,
    gw: &'a mut [f32],
    ght: &'a mut [f32],
    dense: Option<&'a Mat>,
    sparse: Option<&'a crate::data::sparse::BlockEntries>,
    rng: Rng,
}

impl Sampler for Psgld {
    fn step(&mut self, t: u64) {
        let b = self.grid.b();
        let k = self.model.k;
        let mut rng = Rng::derive(self.seed, &[t, 0xcafe]);
        let part = self.scheduler.next_part(&mut rng);
        let eps = self.run_cfg.step.eps(t) as f32;
        let scale = match &self.data {
            DataBlocks::Dense(_) => self.grid.scale_dense(&part),
            DataBlocks::Sparse(bs) => bs.scale(&part),
        };

        // Row-stripe slices of W and column-stripe slices of Ht.
        let row_bounds: Vec<usize> = (0..b).map(|bi| self.grid.row_range(bi).end).collect();
        let col_bounds: Vec<usize> = (0..b).map(|bj| self.grid.col_range(bj).end).collect();
        let w_stripes = Self::stripe_slices(self.state.w.as_mut_slice(), row_bounds.into_iter(), k);
        let ht_stripes =
            Self::stripe_slices(self.state.ht.as_mut_slice(), col_bounds.into_iter(), k);

        // Reorder Ht stripes by the part permutation (block b pairs row
        // stripe b with column stripe perm[b]).
        let mut ht_slots: Vec<Option<&mut [f32]>> = ht_stripes.into_iter().map(Some).collect();

        let mut tasks: Vec<BlockTask> = Vec::with_capacity(b);
        for (bi, (w_slice, scratch_b)) in
            w_stripes.into_iter().zip(self.scratch.iter_mut()).enumerate()
        {
            let bj = part.perm[bi];
            let ht_slice = ht_slots[bj].take().expect("perm is a bijection");
            let m = self.grid.row_range(bi).len();
            let n = self.grid.col_range(bj).len();
            let (gw_buf, ght_buf) = scratch_b;
            gw_buf[..m * k].fill(0.0);
            ght_buf[..n * k].fill(0.0);
            let (gw, ght) = (&mut gw_buf[..m * k], &mut ght_buf[..n * k]);
            let (dense, sparse) = match &self.data {
                DataBlocks::Dense(blocks) => (Some(&blocks[bi * b + bj]), None),
                DataBlocks::Sparse(bs) => (None, Some(bs.block(bi, bj))),
            };
            tasks.push(BlockTask {
                w: w_slice,
                m,
                ht: ht_slice,
                n,
                gw,
                ght,
                dense,
                sparse,
                rng: Rng::derive(self.seed, &[t, bi as u64]),
            });
        }

        let model = &self.model;
        let langevin = self.langevin;
        par_for_each_mut(&mut tasks, self.threads, |_, task| {
            let ll_unused = match (task.dense, task.sparse) {
                (Some(vblk), None) => grads_dense_core(
                    task.w, task.m, task.ht, task.n, k,
                    vblk.as_slice(), model.beta, model.phi,
                    task.gw, task.ght,
                ),
                (None, Some(blk)) => grads_sparse_core(
                    task.w, task.ht, k, blk, model.beta, model.phi,
                    task.gw, task.ght,
                ),
                _ => unreachable!(),
            };
            let _ = ll_unused;
            if langevin {
                sgld_apply_core(
                    task.w, task.gw, eps, scale, model.lam_w, model.mirror,
                    &mut task.rng,
                );
                sgld_apply_core(
                    task.ht, task.ght, eps, scale, model.lam_h, model.mirror,
                    &mut task.rng,
                );
            } else {
                sgd_apply_core(task.w, task.gw, eps, scale, model.lam_w, model.mirror);
                sgd_apply_core(task.ht, task.ght, eps, scale, model.lam_h, model.mirror);
            }
        });
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        if self.langevin {
            "psgld"
        } else {
            "dsgd"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, StepSchedule};
    use crate::data::synth;

    fn quick_run(b: usize, threads: usize, seed: u64) -> (f64, f64, FactorState) {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(32, 32, &model, 11);
        let run = RunConfig::quick(200)
            .with_step(StepSchedule::Polynomial { a: 0.005, b: 0.51 });
        let mut s = Psgld::new(&data.v, &model, b, run.clone(), seed).with_threads(threads);
        let res = s.run(&run);
        (
            res.trace.values[0],
            res.trace.last_value(),
            s.state().clone(),
        )
    }

    #[test]
    fn psgld_improves_loglik() {
        let (first, last, _) = quick_run(4, 1, 13);
        assert!(last > first, "{first} -> {last}");
    }

    #[test]
    fn thread_count_does_not_change_the_chain() {
        // per-block RNG streams are derived from (seed, t, block), so
        // the chain is bitwise identical regardless of thread count
        let (_, last1, s1) = quick_run(4, 1, 17);
        let (_, last4, s4) = quick_run(4, 4, 17);
        assert_eq!(last1, last4);
        assert_eq!(s1.w, s4.w);
        assert_eq!(s1.ht, s4.ht);
    }

    #[test]
    fn mirroring_keeps_nonnegative() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(24, 24, &model, 12);
        let run = RunConfig::quick(50);
        let mut s = Psgld::new(&data.v, &model, 3, run.clone(), 1);
        for t in 1..=50 {
            s.step(t);
        }
        assert!(s.state().w.as_slice().iter().all(|&x| x >= 0.0));
        assert!(s.state().ht.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sparse_psgld_runs_and_improves_rmse() {
        use crate::data::movielens;
        use crate::metrics::rmse_sparse;
        let csr = movielens::movielens_like_dims(60, 80, 900, 4, 3);
        let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
        let run = RunConfig::quick(300)
            .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        let mut s = Psgld::new_sparse(&csr, &model, 4, run.clone(), 5).unwrap();
        let rmse0 = rmse_sparse(&s.state().w, &s.state().h(), &csr);
        for t in 1..=300 {
            s.step(t);
        }
        let rmse1 = rmse_sparse(&s.state().w, &s.state().h(), &csr);
        assert!(rmse1 < rmse0, "{rmse0} -> {rmse1}");
    }

    #[test]
    fn uneven_grid_supported() {
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(25, 31, &model, 14);
        let run = RunConfig::quick(30);
        let mut s = Psgld::new(&data.v, &model, 3, run.clone(), 2);
        for t in 1..=30 {
            s.step(t);
        }
        assert!(s
            .state()
            .w
            .as_slice()
            .iter()
            .all(|x| x.is_finite()));
    }

    #[test]
    fn dense_v_roundtrip() {
        let model = NmfModel::poisson(2);
        let data = synth::poisson_nmf(12, 12, &model, 15);
        let s = Psgld::new(&data.v, &model, 3, RunConfig::quick(10), 3);
        assert_eq!(s.dense_v(), data.v);
    }
}
