//! The samplers: PSGLD (the paper's contribution, shared-memory
//! parallel) and every comparator the evaluation uses — LD, SGLD, the
//! Poisson-NMF Gibbs sampler, DSGD (optimisation baseline) and DSGLD.
//!
//! All samplers share the [`FactorState`] layout (`W: I×K`, `Ht: J×K` —
//! H stored transposed for contiguous column-stripe blocks) and are
//! driven by [`run_sampler`], which owns timing, monitoring and
//! posterior-mean collection so per-sampler code is just `step`.

pub mod block_step;
pub mod coupled;
pub mod dsgd;
pub mod dsgld;
pub mod gibbs;
pub mod ld;
pub mod multichain;
pub mod psgld;
pub mod sgld;

pub use block_step::sparse_block_langevin;
pub use coupled::CoupledPsgld;
pub use dsgd::Dsgd;
pub use dsgld::Dsgld;
pub use gibbs::GibbsPoisson;
pub use ld::Ld;
pub use multichain::{run_chains, MultiChainResult};
pub use psgld::{ExecMode, Psgld};
pub use sgld::Sgld;

use std::time::Instant;

use crate::config::RunConfig;
use crate::linalg::Mat;
use crate::metrics::Trace;
use crate::model::NmfModel;
use crate::obs::{Phase, Span};
use crate::rng::Rng;

/// Factor state `(W, H)` with H stored transposed (`Ht[j][k] = h[k][j]`).
#[derive(Clone, Debug)]
pub struct FactorState {
    /// Dictionary, `I × K`.
    pub w: Mat,
    /// Weights transposed, `J × K`.
    pub ht: Mat,
}

impl FactorState {
    /// Initialise from the model's exponential priors.
    pub fn from_prior(model: &NmfModel, i: usize, j: usize, rng: &mut Rng) -> Self {
        let (w, h) = model.sample_prior(i, j, rng);
        FactorState { w, ht: h.transpose() }
    }

    /// The canonical `K × J` weight matrix (copies).
    pub fn h(&self) -> Mat {
        self.ht.transpose()
    }

    /// `(I, J, K)`.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.w.rows(), self.ht.rows(), self.w.cols())
    }

    /// Reconstruction `|W||H|`.
    pub fn reconstruct(&self) -> Mat {
        self.w.matmul_abs(&self.h()).expect("shape")
    }
}

/// Running posterior mean of `(|W|, |Ht|)` over collected samples (the
/// Monte Carlo averages plotted in Fig. 3).
#[derive(Clone, Debug)]
pub struct PosteriorMean {
    w_sum: Mat,
    ht_sum: Mat,
    count: u64,
}

impl PosteriorMean {
    pub fn new(i: usize, j: usize, k: usize) -> Self {
        PosteriorMean { w_sum: Mat::zeros(i, k), ht_sum: Mat::zeros(j, k), count: 0 }
    }

    pub fn add(&mut self, state: &FactorState) {
        for (acc, &x) in self.w_sum.as_mut_slice().iter_mut().zip(state.w.as_slice()) {
            *acc += x.abs();
        }
        for (acc, &x) in self.ht_sum.as_mut_slice().iter_mut().zip(state.ht.as_slice()) {
            *acc += x.abs();
        }
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Posterior-mean dictionary `E[|W|]`.
    pub fn w_mean(&self) -> Mat {
        let mut m = self.w_sum.clone();
        let c = (self.count.max(1)) as f32;
        for x in m.as_mut_slice() {
            *x /= c;
        }
        m
    }

    /// Posterior-mean weights `E[|H|]` (returned transposed, `J × K`).
    pub fn ht_mean(&self) -> Mat {
        let mut m = self.ht_sum.clone();
        let c = (self.count.max(1)) as f32;
        for x in m.as_mut_slice() {
            *x /= c;
        }
        m
    }
}

/// One MCMC method over a fixed dataset. `step` advances the chain one
/// iteration; the run driver handles everything else.
pub trait Sampler {
    /// Advance the chain by one iteration (`t` is 1-based).
    fn step(&mut self, t: u64);

    /// Current factor state.
    fn state(&self) -> &FactorState;

    /// Model hyper-parameters.
    fn model(&self) -> &NmfModel;

    /// Human-readable name for traces/CSV.
    fn name(&self) -> &'static str;
}

/// Outcome of [`run_sampler`].
pub struct RunResult {
    /// Monitor trace (value vs iteration vs wall seconds; monitor time
    /// is excluded from the clock).
    pub trace: Trace,
    /// Posterior means over post-burn-in (thinned) samples.
    pub posterior: PosteriorMean,
    /// Pure sampling wall time (monitors excluded).
    pub sampling_seconds: f64,
}

/// Drive a sampler for `run.t_total` iterations, recording
/// `monitor(state)` every `run.monitor_every` iterations (monitor cost
/// excluded from the timer) and accumulating posterior means after
/// burn-in with thinning.
pub fn run_sampler<S: Sampler + ?Sized>(
    sampler: &mut S,
    run: &RunConfig,
    mut monitor: impl FnMut(&FactorState) -> f64,
) -> RunResult {
    run.validate().expect("valid run config");
    let (i, j, k) = sampler.state().shape();
    let mut posterior = PosteriorMean::new(i, j, k);
    let mut trace = Trace::new(sampler.name());
    let mut sampling_seconds = 0.0f64;
    let mut monitored = |state: &FactorState| {
        let _monitor_span = Span::enter(Phase::Monitor, "monitor");
        monitor(state)
    };

    // initial monitor point (iteration 0)
    let v0 = monitored(sampler.state());
    trace.push(0, 0.0, v0);
    crate::monitor::observe_sample(0, 0.0, v0);

    for t in 1..=run.t_total {
        let tick = Instant::now();
        sampler.step(t);
        sampling_seconds += tick.elapsed().as_secs_f64();

        if t % run.monitor_every == 0 || t == run.t_total {
            let v = monitored(sampler.state());
            trace.push(t, sampling_seconds, v);
            crate::monitor::observe_sample(t, sampling_seconds, v);
        }
        if t > run.burn_in && (t - run.burn_in) % run.thin == 0 {
            posterior.add(sampler.state());
        }
    }
    RunResult { trace, posterior, sampling_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_state_roundtrip() {
        let model = NmfModel::poisson(3);
        let mut rng = Rng::seed_from(1);
        let s = FactorState::from_prior(&model, 5, 7, &mut rng);
        assert_eq!(s.shape(), (5, 7, 3));
        let h = s.h();
        assert_eq!(h.shape(), (3, 7));
        assert_eq!(h.get(2, 6), s.ht.get(6, 2));
        assert_eq!(s.reconstruct().shape(), (5, 7));
    }

    #[test]
    fn posterior_mean_accumulates() {
        let model = NmfModel::poisson(2);
        let mut rng = Rng::seed_from(2);
        let s1 = FactorState::from_prior(&model, 3, 3, &mut rng);
        let s2 = FactorState::from_prior(&model, 3, 3, &mut rng);
        let mut pm = PosteriorMean::new(3, 3, 2);
        pm.add(&s1);
        pm.add(&s2);
        assert_eq!(pm.count(), 2);
        let expect = 0.5 * (s1.w.get(1, 1).abs() + s2.w.get(1, 1).abs());
        assert!((pm.w_mean().get(1, 1) - expect).abs() < 1e-6);
    }
}
