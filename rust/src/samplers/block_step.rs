//! The canonical per-block Langevin update, shared by every executor.
//!
//! One iteration of PSGLD decomposes into `B` independent block updates
//! (gradient over the block's observed entries + SGLD parameter step on
//! the block's `W` row-stripe and `H_b` column-stripe). Three executors
//! run this body — the shared-memory [`super::Psgld`], the synchronous
//! virtual-time cluster simulator, and the async fault-injecting
//! executor in [`crate::cluster::async_sim`] — and they must stay
//! *bitwise identical* given identical inputs. Centralising the body
//! here makes drift impossible by construction.
//!
//! Determinism contract (load-bearing; tests pin it):
//!
//! * the per-block RNG stream is derived from `(seed, t, block)` and
//!   **nothing else** — never the worker slot, never the event-queue pop
//!   order, never wall-clock state;
//! * the noise draws go `W` first, then `Ht`, from the same stream;
//! * gradient accumulators are zeroed here, so callers can reuse
//!   scratch without washing it themselves.

use crate::data::sparse::BlockEntries;
use crate::kernels::{grads_sparse_core, sgld_apply_core};
use crate::model::NmfModel;
use crate::obs::{counter_add, Counter, Phase, Span};
use crate::rng::Rng;
use crate::util::parallel::ScratchArena;

/// One sparse-data block-Langevin update: accumulate the block gradient
/// into `(gw, ght)` and apply the SGLD step to `w` (the `m × k` row
/// stripe) and `ht` (the `n × k` column stripe, stored transposed), with
/// the noise stream keyed by `(seed, t, block)`.
///
/// `nonneg` is the hoisted once-per-part fast-path decision (see
/// [`crate::kernels::nonneg_hint`]); it must be computed identically by
/// every executor that wants bitwise-equal chains.
#[allow(clippy::too_many_arguments)]
pub fn sparse_block_langevin(
    w: &mut [f32],
    ht: &mut [f32],
    k: usize,
    blk: &BlockEntries,
    model: &NmfModel,
    nonneg: bool,
    eps: f32,
    scale: f32,
    seed: u64,
    t: u64,
    block: u64,
    gw: &mut [f32],
    ght: &mut [f32],
    arena: &mut ScratchArena,
) {
    debug_assert_eq!(gw.len(), w.len());
    debug_assert_eq!(ght.len(), ht.len());
    counter_add(Counter::Blocks, 1);
    {
        let _kernel_span = Span::enter(Phase::Kernel, "grads_sparse");
        gw.fill(0.0);
        ght.fill(0.0);
        let _ = grads_sparse_core(w, ht, k, blk, model.beta, model.phi, nonneg, gw, ght);
    }
    let _noise_span = Span::enter(Phase::Noise, "langevin_apply");
    // Per-block stream keyed by (seed, t, block) — independent of which
    // worker slot or event-loop turn executes the block.
    let mut brng = Rng::derive(seed, &[t, block]);
    sgld_apply_core(w, gw, eps, scale, model.lam_w, model.mirror, &mut brng, arena);
    sgld_apply_core(ht, ght, eps, scale, model.lam_h, model.mirror, &mut brng, arena);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::movielens;
    use crate::data::sparse::BlockedSparse;
    use crate::kernels::nonneg_hint;

    #[test]
    fn repeated_call_with_same_tags_is_bitwise_identical() {
        let csr = movielens::movielens_like_dims(24, 30, 200, 3, 41);
        let blocked = BlockedSparse::from_csr(&csr, 3).unwrap();
        let grid = blocked.grid().clone();
        let model = NmfModel::poisson(3);
        let mut rng = Rng::seed_from(7);
        let k = model.k;
        let (m, n) = (grid.row_range(0).len(), grid.col_range(1).len());
        let w0: Vec<f32> = (0..m * k).map(|_| rng.next_f32() + 0.1).collect();
        let h0: Vec<f32> = (0..n * k).map(|_| rng.next_f32() + 0.1).collect();
        let nonneg = nonneg_hint(model.mirror, &w0, &h0, csr.nnz());

        let run_once = || {
            let (mut w, mut ht) = (w0.clone(), h0.clone());
            let mut gw = vec![0f32; m * k];
            let mut ght = vec![0f32; n * k];
            let mut arena = ScratchArena::new();
            sparse_block_langevin(
                &mut w,
                &mut ht,
                k,
                blocked.block(0, 1),
                &model,
                nonneg,
                0.01,
                1.5,
                99,
                5,
                0,
                &mut gw,
                &mut ght,
                &mut arena,
            );
            (w, ht)
        };
        let (w_a, h_a) = run_once();
        let (w_b, h_b) = run_once();
        assert_eq!(w_a, w_b);
        assert_eq!(h_a, h_b);
    }
}
