//! DSGLD (Ahn, Shahbaba & Welling 2014) — the generic distributed SGLD
//! the paper builds on and criticises (§1): `C` workers each hold a data
//! shard and a **full copy** of `(W, H)`; every worker runs SGLD against
//! its shard, and all parameters are synchronised (averaged) every
//! `sync_every` iterations.
//!
//! Two inefficiencies relative to PSGLD — both reproduced here, and both
//! measured by the cluster simulator's communication model:
//!   1. every sync ships *all* of W and H (PSGLD ships one `H_b` block
//!      per iteration);
//!   2. the latent factors are replicated per worker instead of being
//!      partitioned, so memory scales with `C · (I + J) · K`.

use crate::config::StepSchedule;
use crate::kernels::sgld_apply;
use crate::linalg::Mat;
use crate::model::tweedie::{grad_error, MU_EPS};
use crate::model::NmfModel;
use crate::rng::Rng;
use crate::samplers::{FactorState, Sampler};

/// One DSGLD worker: a shard (column range) and a full chain replica.
struct Worker {
    col_range: std::ops::Range<usize>,
    state: FactorState,
    rng: Rng,
    gw: Mat,
    ght: Mat,
}

/// Distributed SGLD with periodic full-parameter synchronisation.
pub struct Dsgld {
    v: Mat,
    model: NmfModel,
    step: StepSchedule,
    /// Sub-sample size per worker per iteration.
    pub omega: usize,
    /// Average all replicas every this many iterations.
    pub sync_every: u64,
    workers: Vec<Worker>,
    /// Exposed chain (worker 0's replica).
    exposed: FactorState,
}

impl Dsgld {
    pub fn new(
        v: &Mat,
        model: &NmfModel,
        n_workers: usize,
        omega: usize,
        sync_every: u64,
        step: StepSchedule,
        seed: u64,
    ) -> Self {
        assert!(n_workers >= 1 && n_workers <= v.cols());
        let mut init_rng = Rng::derive(seed, &[0xd5_91d]);
        let shared = FactorState::from_prior(model, v.rows(), v.cols(), &mut init_rng);
        let cols_per = v.cols() / n_workers;
        let workers = (0..n_workers)
            .map(|c| {
                let start = c * cols_per;
                let end = if c + 1 == n_workers { v.cols() } else { start + cols_per };
                Worker {
                    col_range: start..end,
                    state: shared.clone(),
                    rng: Rng::derive(seed, &[0xd5_91d, c as u64 + 1]),
                    gw: Mat::zeros(v.rows(), model.k),
                    ght: Mat::zeros(v.cols(), model.k),
                }
            })
            .collect();
        Dsgld {
            v: v.clone(),
            model: model.clone(),
            step,
            omega: omega.max(1),
            sync_every: sync_every.max(1),
            workers,
            exposed: shared,
        }
    }

    /// Bytes shipped per synchronisation (all replicas exchange full
    /// parameters) — the quantity the cluster simulator charges.
    pub fn sync_bytes(&self) -> usize {
        let (i, j, k) = self.exposed.shape();
        self.workers.len() * (i + j) * k * std::mem::size_of::<f32>()
    }

    fn sync(&mut self) {
        // parameter averaging across replicas
        let c = self.workers.len() as f32;
        let (i, j, k) = self.exposed.shape();
        let mut w_avg = Mat::zeros(i, k);
        let mut ht_avg = Mat::zeros(j, k);
        for wk in &self.workers {
            w_avg.axpy(1.0 / c, &wk.state.w).expect("shape");
            ht_avg.axpy(1.0 / c, &wk.state.ht).expect("shape");
        }
        for wk in &mut self.workers {
            wk.state.w = w_avg.clone();
            wk.state.ht = ht_avg.clone();
        }
        self.exposed = FactorState { w: w_avg, ht: ht_avg };
    }
}

impl Sampler for Dsgld {
    fn step(&mut self, t: u64) {
        let eps = self.step.eps(t) as f32;
        let (i_rows, _, k) = self.exposed.shape();
        let n_total = (self.v.rows() * self.v.cols()) as f32;
        let model = &self.model;
        let v = &self.v;
        let omega = self.omega;

        for wk in &mut self.workers {
            wk.gw.as_mut_slice().fill(0.0);
            wk.ght.as_mut_slice().fill(0.0);
            let shard_cols = wk.col_range.len();
            for _ in 0..omega {
                let ri = wk.rng.next_below(i_rows as u64) as usize;
                let rj = wk.col_range.start
                    + wk.rng.next_below(shard_cols as u64) as usize;
                let wrow = wk.state.w.row(ri);
                let htrow = wk.state.ht.row(rj);
                let mut mu = MU_EPS;
                for kk in 0..k {
                    mu += wrow[kk].abs() * htrow[kk].abs();
                }
                let e = grad_error(v.get(ri, rj), mu, model.beta, model.phi);
                let gwrow = wk.gw.row_mut(ri);
                for kk in 0..k {
                    let s = if wrow[kk] == 0.0 { 0.0 } else { wrow[kk].signum() };
                    gwrow[kk] += e * s * htrow[kk].abs();
                }
                let ghtrow = wk.ght.row_mut(rj);
                for kk in 0..k {
                    let s = if htrow[kk] == 0.0 { 0.0 } else { htrow[kk].signum() };
                    ghtrow[kk] += e * s * wrow[kk].abs();
                }
            }
            // scale: shard fraction of N over the subsample
            let scale = n_total / omega as f32;
            sgld_apply(
                &mut wk.state.w, &wk.gw, eps, scale, model.lam_w, model.mirror,
                &mut wk.rng,
            );
            sgld_apply(
                &mut wk.state.ht, &wk.ght, eps, scale, model.lam_h, model.mirror,
                &mut wk.rng,
            );
        }

        if t % self.sync_every == 0 {
            self.sync();
        } else {
            self.exposed = self.workers[0].state.clone();
        }
    }

    fn state(&self) -> &FactorState {
        &self.exposed
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "dsgld"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::synth;
    use crate::samplers::run_sampler;

    #[test]
    fn dsgld_improves_loglik() {
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(24, 24, &model, 41);
        let mut d = Dsgld::new(
            &data.v, &model, 3, 64, 5,
            StepSchedule::Polynomial { a: 5e-4, b: 0.51 }, 42,
        );
        let run = RunConfig::quick(250);
        let res = run_sampler(&mut d, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        assert!(res.trace.last_value() > res.trace.values[0]);
    }

    #[test]
    fn sync_brings_replicas_together() {
        let model = NmfModel::poisson(2);
        let data = synth::poisson_nmf(12, 12, &model, 43);
        let mut d = Dsgld::new(
            &data.v, &model, 4, 16, 3,
            StepSchedule::Polynomial { a: 1e-3, b: 0.51 }, 44,
        );
        d.step(1);
        d.step(2);
        // before sync: replicas differ
        assert_ne!(d.workers[0].state.w, d.workers[1].state.w);
        d.step(3); // sync_every = 3 triggers here
        for c in 1..4 {
            assert_eq!(d.workers[0].state.w, d.workers[c].state.w);
        }
    }

    #[test]
    fn sync_bytes_scale_with_workers_and_size() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(16, 32, &model, 45);
        let d2 = Dsgld::new(&data.v, &model, 2, 8, 2,
                            StepSchedule::paper_sgld(), 46);
        let d4 = Dsgld::new(&data.v, &model, 4, 8, 2,
                            StepSchedule::paper_sgld(), 46);
        assert_eq!(d2.sync_bytes(), 2 * (16 + 32) * 4 * 4);
        assert_eq!(d4.sync_bytes(), 2 * d2.sync_bytes());
    }
}
