//! DSGD (Gemulla et al. 2011) — the distributed *optimisation* baseline
//! of Fig. 5: exactly the PSGLD block machinery with the Langevin noise
//! removed (stochastic gradient ascent on the log posterior, i.e. a MAP
//! method). Sharing the implementation makes the Fig. 5 comparison an
//! apples-to-apples measurement of "the cost of being Bayesian":
//! identical partitioning, scheduling, parallelism and memory traffic —
//! the only delta is the injected noise.

use crate::config::RunConfig;
use crate::data::sparse::Csr;
use crate::linalg::Mat;
use crate::model::NmfModel;
use crate::samplers::{ExecMode, FactorState, Psgld, RunResult, Sampler};
use crate::Result;

/// Distributed (block-parallel) stochastic gradient descent.
pub struct Dsgd(Psgld);

impl Dsgd {
    pub fn new(v: &Mat, model: &NmfModel, b: usize, run: RunConfig, seed: u64) -> Self {
        let mut inner = Psgld::new(v, model, b, run, seed);
        inner.langevin = false;
        Dsgd(inner)
    }

    pub fn new_sparse(
        v: &Csr,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> Result<Self> {
        let mut inner = Psgld::new_sparse(v, model, b, run, seed)?;
        inner.langevin = false;
        Ok(Dsgd(inner))
    }

    pub fn with_threads(self, threads: usize) -> Self {
        Dsgd(self.0.with_threads(threads))
    }

    pub fn with_exec_mode(self, exec: ExecMode) -> Self {
        Dsgd(self.0.with_exec_mode(exec))
    }

    pub fn with_state(self, state: FactorState) -> Self {
        Dsgd(self.0.with_state(state))
    }

    /// Run with the default monitor (see [`Psgld::run`]).
    pub fn run(&mut self, run: &RunConfig) -> RunResult {
        self.0.run(run)
    }
}

impl Sampler for Dsgd {
    fn step(&mut self, t: u64) {
        self.0.step(t)
    }

    fn state(&self) -> &FactorState {
        self.0.state()
    }

    fn model(&self) -> &NmfModel {
        self.0.model()
    }

    fn name(&self) -> &'static str {
        "dsgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, StepSchedule};
    use crate::data::synth;
    use crate::metrics::rmse_dense;

    #[test]
    fn dsgd_reduces_rmse_deterministically() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(32, 32, &model, 31);
        let run = RunConfig::quick(200)
            .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
        let mut a = Dsgd::new(&data.v, &model, 4, run.clone(), 7);
        let mut b = Dsgd::new(&data.v, &model, 4, run.clone(), 7);
        let rmse0 = rmse_dense(&a.state().w, &a.state().h(), &data.v);
        for t in 1..=200 {
            a.step(t);
            b.step(t);
        }
        let rmse1 = rmse_dense(&a.state().w, &a.state().h(), &data.v);
        assert!(rmse1 < rmse0, "{rmse0} -> {rmse1}");
        // no noise: two runs with the same seed agree exactly
        assert_eq!(a.state().w, b.state().w);
    }

    #[test]
    fn dsgd_name_and_model() {
        let model = NmfModel::poisson(2);
        let data = synth::poisson_nmf(8, 8, &model, 32);
        let d = Dsgd::new(&data.v, &model, 2, RunConfig::quick(10), 1);
        assert_eq!(d.name(), "dsgd");
        assert_eq!(d.model().k, 2);
    }
}
