//! Langevin dynamics (LD) baseline: full-batch gradient over the whole
//! observed matrix at every iteration plus `N(0, 2ε)` noise — the
//! classical (non-stochastic) gradient MCMC comparator of Fig. 2.

use crate::config::StepSchedule;
use crate::kernels::{dense_block_grads, sgld_apply};
use crate::linalg::Mat;
use crate::model::NmfModel;
use crate::rng::Rng;
use crate::samplers::{FactorState, Sampler};

/// Full-batch Langevin sampler over a dense observed matrix.
pub struct Ld {
    v: Mat,
    model: NmfModel,
    state: FactorState,
    step: StepSchedule,
    rng: Rng,
}

impl Ld {
    pub fn new(v: &Mat, model: &NmfModel, step: StepSchedule, seed: u64) -> Self {
        let mut rng = Rng::derive(seed, &[0x1d]);
        let state = FactorState::from_prior(model, v.rows(), v.cols(), &mut rng);
        Ld { v: v.clone(), model: model.clone(), state, step, rng }
    }

    /// Replace the state (e.g. to start several methods identically).
    pub fn with_state(mut self, state: FactorState) -> Self {
        self.state = state;
        self
    }
}

impl Sampler for Ld {
    fn step(&mut self, t: u64) {
        let eps = self.step.eps(t) as f32;
        let g = dense_block_grads(
            &self.state.w,
            &self.state.ht,
            &self.v,
            self.model.beta,
            self.model.phi,
        );
        sgld_apply(
            &mut self.state.w,
            &g.gw,
            eps,
            1.0,
            self.model.lam_w,
            self.model.mirror,
            &mut self.rng,
        );
        sgld_apply(
            &mut self.state.ht,
            &g.ght,
            eps,
            1.0,
            self.model.lam_h,
            self.model.mirror,
            &mut self.rng,
        );
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "ld"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::synth;
    use crate::samplers::run_sampler;

    #[test]
    fn ld_improves_loglik_from_prior_init() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(24, 24, &model, 3);
        let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 2e-4 }, 7);
        let run = RunConfig::quick(150);
        let res = run_sampler(&mut ld, &run, |s| {
            model.loglik_dense(&s.w, &s.h(), &data.v)
        });
        assert!(
            res.trace.last_value() > res.trace.values[0],
            "loglik should improve: {:?} -> {:?}",
            res.trace.values[0],
            res.trace.last_value()
        );
        assert_eq!(res.posterior.count(), 75);
    }

    #[test]
    fn mirroring_keeps_state_nonnegative() {
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(16, 16, &model, 4);
        let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 0.05 }, 8);
        for t in 1..=20 {
            ld.step(t);
        }
        assert!(ld.state().w.as_slice().iter().all(|&x| x >= 0.0));
        assert!(ld.state().ht.as_slice().iter().all(|&x| x >= 0.0));
    }
}
