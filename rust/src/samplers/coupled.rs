//! Coupled matrix factorisation — the extension the paper's conclusion
//! singles out ("it is rather straightforward to extend PSGLD to more
//! structured models such as coupled matrix and tensor factorisation"):
//! two observed matrices share the dictionary,
//!
//!   `V1 ≈ |W||H1|  (I×J)`,   `V2 ≈ |W||H2|  (I×L)`,
//!
//! e.g. ratings + item-content, or audio spectra from two recordings of
//! the same instruments. PSGLD extends exactly as advertised: the row
//! grid over `[I]` is shared; each iteration picks one part per matrix;
//! block `b` updates `W_b` with the *sum* of both matrices' (debiased)
//! gradients, and `H1`/`H2` blocks with their own — all B block-tasks
//! still conditionally independent, so the parallel structure is
//! unchanged (Yilmaz et al. 2011's GCTF view, specialised to two
//! observations).

use crate::config::RunConfig;
use crate::kernels::{grads_dense_core, sgld_apply_core};
use crate::linalg::Mat;
use crate::model::NmfModel;
use crate::partition::{GridPartition, PartScheduler};
use crate::rng::Rng;
use crate::samplers::{FactorState, Sampler};
use crate::util::parallel::{default_threads, par_for_each_mut};

/// Shared-dictionary coupled factorisation state.
#[derive(Clone, Debug)]
pub struct CoupledState {
    /// Shared dictionary, `I × K`.
    pub w: Mat,
    /// First weight matrix, transposed (`J × K`).
    pub ht1: Mat,
    /// Second weight matrix, transposed (`L × K`).
    pub ht2: Mat,
}

/// PSGLD for the two-matrix coupled model. Both observations use the
/// same Tweedie β/φ and the shared-`W` prior; weights have their own
/// priors via the `model` field (lam_h applies to both).
pub struct CoupledPsgld {
    model: NmfModel,
    grid1: GridPartition,
    grid2: GridPartition,
    v1_blocks: Vec<Mat>,
    v2_blocks: Vec<Mat>,
    state: CoupledState,
    sched1: PartScheduler,
    sched2: PartScheduler,
    run_cfg: RunConfig,
    seed: u64,
    threads: usize,
    /// Exposed (W, H1) view for the `Sampler` trait.
    exposed: FactorState,
}

impl CoupledPsgld {
    pub fn new(
        v1: &Mat,
        v2: &Mat,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> crate::Result<Self> {
        if v1.rows() != v2.rows() {
            return Err(crate::Error::Shape(format!(
                "coupled matrices must share rows: {} vs {}",
                v1.rows(),
                v2.rows()
            )));
        }
        let grid1 = GridPartition::new(v1.rows(), v1.cols(), b)?;
        let grid2 = GridPartition::new(v2.rows(), v2.cols(), b)?;
        let slice = |v: &Mat, g: &GridPartition| -> Vec<Mat> {
            (0..b)
                .flat_map(|bi| {
                    let (v, g) = (v.clone(), g.clone());
                    (0..b).map(move |bj| {
                        let (r, c) = (g.row_range(bi), g.col_range(bj));
                        v.slice_block(r.start, r.end, c.start, c.end)
                    })
                })
                .collect()
        };
        let mut rng = Rng::derive(seed, &[0xc0_0b1e]);
        let w = Mat::exponential(v1.rows(), model.k, model.lam_w as f64, &mut rng);
        let ht1 = Mat::exponential(v1.cols(), model.k, model.lam_h as f64, &mut rng);
        let ht2 = Mat::exponential(v2.cols(), model.k, model.lam_h as f64, &mut rng);
        let state = CoupledState { w, ht1, ht2 };
        let exposed = FactorState { w: state.w.clone(), ht: state.ht1.clone() };
        Ok(CoupledPsgld {
            model: model.clone(),
            v1_blocks: slice(v1, &grid1),
            v2_blocks: slice(v2, &grid2),
            grid1,
            grid2,
            state,
            sched1: PartScheduler::new(run.schedule, b),
            sched2: PartScheduler::new(run.schedule, b),
            run_cfg: run,
            seed,
            threads: default_threads().min(b),
            exposed,
        })
    }

    pub fn coupled_state(&self) -> &CoupledState {
        &self.state
    }

    /// Joint unnormalised data log-likelihood over both matrices.
    pub fn loglik(&self, v1: &Mat, v2: &Mat) -> f64 {
        self.model.loglik_dense(&self.state.w, &self.state.ht1.transpose(), v1)
            + self.model.loglik_dense(&self.state.w, &self.state.ht2.transpose(), v2)
    }

    fn stripe_slices<'a>(
        data: &'a mut [f32],
        grid: &GridPartition,
        k: usize,
        rows: bool,
    ) -> Vec<&'a mut [f32]> {
        let b = grid.b();
        let bounds: Vec<usize> = (0..b)
            .map(|i| if rows { grid.row_range(i).end } else { grid.col_range(i).end })
            .collect();
        let mut out = Vec::new();
        let mut rest = data;
        let mut prev = 0usize;
        for bound in bounds {
            let (head, tail) = rest.split_at_mut((bound - prev) * k);
            out.push(head);
            rest = tail;
            prev = bound;
        }
        out
    }
}

struct CoupledTask<'a> {
    w: &'a mut [f32],
    m: usize,
    ht1: &'a mut [f32],
    n1: usize,
    ht2: &'a mut [f32],
    n2: usize,
    v1: &'a Mat,
    v2: &'a Mat,
    rng: Rng,
}

impl Sampler for CoupledPsgld {
    fn step(&mut self, t: u64) {
        let b = self.grid1.b();
        let k = self.model.k;
        let mut rng = Rng::derive(self.seed, &[t, 0xc0]);
        let part1 = self.sched1.next_part(&mut rng);
        let part2 = self.sched2.next_part(&mut rng);
        let eps = self.run_cfg.step.eps(t) as f32;
        let scale1 = self.grid1.scale_dense(&part1);
        let scale2 = self.grid2.scale_dense(&part2);

        let w_stripes = Self::stripe_slices(self.state.w.as_mut_slice(), &self.grid1, k, true);
        let ht1_stripes =
            Self::stripe_slices(self.state.ht1.as_mut_slice(), &self.grid1, k, false);
        let ht2_stripes =
            Self::stripe_slices(self.state.ht2.as_mut_slice(), &self.grid2, k, false);
        let mut s1: Vec<Option<&mut [f32]>> = ht1_stripes.into_iter().map(Some).collect();
        let mut s2: Vec<Option<&mut [f32]>> = ht2_stripes.into_iter().map(Some).collect();

        let mut tasks: Vec<CoupledTask> = Vec::with_capacity(b);
        for (bi, w_slice) in w_stripes.into_iter().enumerate() {
            let bj1 = part1.perm[bi];
            let bj2 = part2.perm[bi];
            tasks.push(CoupledTask {
                w: w_slice,
                m: self.grid1.row_range(bi).len(),
                ht1: s1[bj1].take().expect("bijection"),
                n1: self.grid1.col_range(bj1).len(),
                ht2: s2[bj2].take().expect("bijection"),
                n2: self.grid2.col_range(bj2).len(),
                v1: &self.v1_blocks[bi * b + bj1],
                v2: &self.v2_blocks[bi * b + bj2],
                rng: Rng::derive(self.seed, &[t, bi as u64, 0xc0]),
            });
        }

        let model = &self.model;
        par_for_each_mut(&mut tasks, self.threads, |_, task| {
            let mut gw = vec![0f32; task.m * k];
            let mut gw2 = vec![0f32; task.m * k];
            let mut g1 = vec![0f32; task.n1 * k];
            let mut g2 = vec![0f32; task.n2 * k];
            grads_dense_core(
                task.w, task.m, task.ht1, task.n1, k,
                task.v1.as_slice(), model.beta, model.phi, &mut gw, &mut g1,
            );
            grads_dense_core(
                task.w, task.m, task.ht2, task.n2, k,
                task.v2.as_slice(), model.beta, model.phi, &mut gw2, &mut g2,
            );
            // W feels both (debiased) data terms
            for (a, &x) in gw.iter_mut().zip(gw2.iter()) {
                *a = scale1 * *a + scale2 * x;
            }
            sgld_apply_core(task.w, &gw, eps, 1.0, model.lam_w, model.mirror, &mut task.rng);
            sgld_apply_core(task.ht1, &g1, eps, scale1, model.lam_h, model.mirror, &mut task.rng);
            sgld_apply_core(task.ht2, &g2, eps, scale2, model.lam_h, model.mirror, &mut task.rng);
        });

        self.exposed = FactorState { w: self.state.w.clone(), ht: self.state.ht1.clone() };
    }

    fn state(&self) -> &FactorState {
        &self.exposed
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "coupled_psgld"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StepSchedule;
    use crate::rng::Dist;

    fn coupled_data(i: usize, j: usize, l: usize, k: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::seed_from(seed);
        let w = Mat::exponential(i, k, 1.0, &mut rng);
        let h1 = Mat::exponential(k, j, 1.0, &mut rng);
        let h2 = Mat::exponential(k, l, 1.0, &mut rng);
        let mu1 = w.matmul_abs(&h1).unwrap();
        let mu2 = w.matmul_abs(&h2).unwrap();
        let v1 = Mat::from_fn(i, j, |r, c| rng.poisson(mu1.get(r, c) as f64) as f32);
        let v2 = Mat::from_fn(i, l, |r, c| rng.poisson(mu2.get(r, c) as f64) as f32);
        (w, v1, v2)
    }

    #[test]
    fn coupled_improves_joint_loglik() {
        let (_, v1, v2) = coupled_data(24, 24, 18, 4, 1);
        let model = NmfModel::poisson(4);
        let run = RunConfig::quick(300)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
        let mut s = CoupledPsgld::new(&v1, &v2, &model, 3, run, 2).unwrap();
        let before = s.loglik(&v1, &v2);
        for t in 1..=300 {
            s.step(t);
        }
        let after = s.loglik(&v1, &v2);
        assert!(after > before, "{before} -> {after}");
        assert!(s.coupled_state().w.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sharing_w_beats_ignoring_second_matrix_when_v1_scarce() {
        // the whole point of coupling: V2 informs W, improving the fit
        // achievable on V1's held-in data when V1 alone is weak. Proxy:
        // reconstruction of the (noiseless) mu1 from the learned W.
        let mut rng = Rng::seed_from(3);
        let (i, j, l, k) = (24usize, 6usize, 48usize, 3usize);
        let w = Mat::exponential(i, k, 1.0, &mut rng);
        let h1 = Mat::exponential(k, j, 1.0, &mut rng);
        let h2 = Mat::exponential(k, l, 1.0, &mut rng);
        let mu1 = w.matmul_abs(&h1).unwrap();
        let v1 = Mat::from_fn(i, j, |r, c| rng.poisson(mu1.get(r, c) as f64) as f32);
        let mu2 = w.matmul_abs(&h2).unwrap();
        let v2 = Mat::from_fn(i, l, |r, c| rng.poisson(mu2.get(r, c) as f64) as f32);

        let model = NmfModel::poisson(k);
        let run = RunConfig::quick(800)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
        let mut coupled = CoupledPsgld::new(&v1, &v2, &model, 3, run.clone(), 4).unwrap();
        for t in 1..=800 {
            coupled.step(t);
        }
        let rec_c = crate::metrics::rmse_dense(
            &coupled.coupled_state().w,
            &coupled.coupled_state().ht1.transpose(),
            &mu1,
        );

        let mut solo = crate::samplers::Psgld::new(&v1, &model, 3, run.clone(), 4);
        for t in 1..=800 {
            solo.step(t);
        }
        let rec_s =
            crate::metrics::rmse_dense(&solo.state().w, &solo.state().h(), &mu1);
        assert!(
            rec_c < rec_s * 1.05,
            "coupled {rec_c} should beat (or match) solo {rec_s} on scarce V1"
        );
    }

    #[test]
    fn rejects_row_mismatch() {
        let model = NmfModel::poisson(2);
        let v1 = Mat::zeros(8, 8);
        let v2 = Mat::zeros(9, 8);
        assert!(CoupledPsgld::new(&v1, &v2, &model, 2, RunConfig::quick(10), 1).is_err());
    }
}
