//! Coupled matrix factorisation — the extension the paper's conclusion
//! singles out ("it is rather straightforward to extend PSGLD to more
//! structured models such as coupled matrix and tensor factorisation"):
//! two observed matrices share the dictionary,
//!
//!   `V1 ≈ |W||H1|  (I×J)`,   `V2 ≈ |W||H2|  (I×L)`,
//!
//! e.g. ratings + item-content, or audio spectra from two recordings of
//! the same instruments. PSGLD extends exactly as advertised: the row
//! grid over `[I]` is shared; each iteration picks one part per matrix;
//! block `b` updates `W_b` with the *sum* of both matrices' (debiased)
//! gradients, and `H1`/`H2` blocks with their own — all B block-tasks
//! still conditionally independent, so the parallel structure is
//! unchanged (Yilmaz et al. 2011's GCTF view, specialised to two
//! observations).

use crate::config::RunConfig;
use crate::kernels::{grads_dense_tiled, sgld_apply_core};
use crate::linalg::Mat;
use crate::model::NmfModel;
use crate::partition::{GridPartition, Part, PartScheduler};
use crate::rng::Rng;
use crate::samplers::{FactorState, Sampler};
use crate::util::parallel::{default_threads, ScratchArena, SendPtr, WorkerPool};

/// Shared-dictionary coupled factorisation state.
#[derive(Clone, Debug)]
pub struct CoupledState {
    /// Shared dictionary, `I × K`.
    pub w: Mat,
    /// First weight matrix, transposed (`J × K`).
    pub ht1: Mat,
    /// Second weight matrix, transposed (`L × K`).
    pub ht2: Mat,
}

/// PSGLD for the two-matrix coupled model. Both observations use the
/// same Tweedie β/φ and the shared-`W` prior; weights have their own
/// priors via the `model` field (lam_h applies to both).
pub struct CoupledPsgld {
    model: NmfModel,
    grid1: GridPartition,
    grid2: GridPartition,
    v1_blocks: Vec<Mat>,
    v2_blocks: Vec<Mat>,
    state: CoupledState,
    sched1: PartScheduler,
    sched2: PartScheduler,
    run_cfg: RunConfig,
    seed: u64,
    /// Persistent workers (with per-worker kernel scratch arenas).
    pool: WorkerPool,
    /// Reusable part buffers, one per observed matrix.
    part1: Part,
    part2: Part,
    /// Per-block gradient accumulators `(gw, gw2, g1, g2)`, reused
    /// across iterations.
    scratch: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>,
    /// Exposed (W, H1) view for the `Sampler` trait.
    exposed: FactorState,
}

impl CoupledPsgld {
    pub fn new(
        v1: &Mat,
        v2: &Mat,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> crate::Result<Self> {
        if v1.rows() != v2.rows() {
            return Err(crate::Error::Shape(format!(
                "coupled matrices must share rows: {} vs {}",
                v1.rows(),
                v2.rows()
            )));
        }
        let grid1 = GridPartition::new(v1.rows(), v1.cols(), b)?;
        let grid2 = GridPartition::new(v2.rows(), v2.cols(), b)?;
        let slice = |v: &Mat, g: &GridPartition| -> Vec<Mat> {
            (0..b)
                .flat_map(|bi| {
                    let (v, g) = (v.clone(), g.clone());
                    (0..b).map(move |bj| {
                        let (r, c) = (g.row_range(bi), g.col_range(bj));
                        v.slice_block(r.start, r.end, c.start, c.end)
                    })
                })
                .collect()
        };
        let mut rng = Rng::derive(seed, &[0xc0_0b1e]);
        let w = Mat::exponential(v1.rows(), model.k, model.lam_w as f64, &mut rng);
        let ht1 = Mat::exponential(v1.cols(), model.k, model.lam_h as f64, &mut rng);
        let ht2 = Mat::exponential(v2.cols(), model.k, model.lam_h as f64, &mut rng);
        let state = CoupledState { w, ht1, ht2 };
        let exposed = FactorState { w: state.w.clone(), ht: state.ht1.clone() };
        let k = model.k;
        let max_n1 = (0..b).map(|bj| grid1.col_range(bj).len()).max().unwrap_or(0);
        let max_n2 = (0..b).map(|bj| grid2.col_range(bj).len()).max().unwrap_or(0);
        let scratch = (0..b)
            .map(|bi| {
                let m = grid1.row_range(bi).len();
                (
                    vec![0f32; m * k],
                    vec![0f32; m * k],
                    vec![0f32; max_n1 * k],
                    vec![0f32; max_n2 * k],
                )
            })
            .collect();
        Ok(CoupledPsgld {
            model: model.clone(),
            v1_blocks: slice(v1, &grid1),
            v2_blocks: slice(v2, &grid2),
            state,
            sched1: PartScheduler::new(run.schedule, b),
            sched2: PartScheduler::new(run.schedule, b),
            run_cfg: run,
            seed,
            pool: WorkerPool::new(default_threads().min(b)),
            part1: Part::identity(b),
            part2: Part::identity(b),
            scratch,
            grid1,
            grid2,
            exposed,
        })
    }

    pub fn coupled_state(&self) -> &CoupledState {
        &self.state
    }

    /// Joint unnormalised data log-likelihood over both matrices.
    pub fn loglik(&self, v1: &Mat, v2: &Mat) -> f64 {
        self.model.loglik_dense(&self.state.w, &self.state.ht1.transpose(), v1)
            + self.model.loglik_dense(&self.state.w, &self.state.ht2.transpose(), v2)
    }

}

impl Sampler for CoupledPsgld {
    fn step(&mut self, t: u64) {
        let b = self.grid1.b();
        let k = self.model.k;
        let mut rng = Rng::derive(self.seed, &[t, 0xc0]);
        self.sched1.next_part_into(&mut rng, &mut self.part1);
        self.sched2.next_part_into(&mut rng, &mut self.part2);
        let eps = self.run_cfg.step.eps(t) as f32;
        let scale1 = self.grid1.scale_dense(&self.part1);
        let scale2 = self.grid2.scale_dense(&self.part2);

        let w_ptr = SendPtr::new(self.state.w.as_mut_slice().as_mut_ptr());
        let ht1_ptr = SendPtr::new(self.state.ht1.as_mut_slice().as_mut_ptr());
        let ht2_ptr = SendPtr::new(self.state.ht2.as_mut_slice().as_mut_ptr());
        let scratch_ptr = SendPtr::new(self.scratch.as_mut_ptr());

        let model = &self.model;
        let grid1 = &self.grid1;
        let grid2 = &self.grid2;
        let part1 = &self.part1;
        let part2 = &self.part2;
        let v1_blocks = &self.v1_blocks;
        let v2_blocks = &self.v2_blocks;
        let seed = self.seed;

        self.pool.for_each_index(b, move |arena: &mut ScratchArena, bi: usize| {
            let bj1 = part1.perm[bi];
            let bj2 = part2.perm[bi];
            let rows = grid1.row_range(bi);
            let cols1 = grid1.col_range(bj1);
            let cols2 = grid2.col_range(bj2);
            let (m, n1, n2) = (rows.len(), cols1.len(), cols2.len());
            // SAFETY: W row stripes are disjoint across bi; H1/H2 column
            // stripes are disjoint across bj1 = part1.perm[bi] (resp.
            // part2) because the part permutations are bijections;
            // scratch[bi] is touched by exactly one task.
            let w = unsafe {
                std::slice::from_raw_parts_mut(w_ptr.get().add(rows.start * k), m * k)
            };
            let ht1 = unsafe {
                std::slice::from_raw_parts_mut(ht1_ptr.get().add(cols1.start * k), n1 * k)
            };
            let ht2 = unsafe {
                std::slice::from_raw_parts_mut(ht2_ptr.get().add(cols2.start * k), n2 * k)
            };
            let sb = unsafe { &mut *scratch_ptr.get().add(bi) };
            let gw = &mut sb.0[..m * k];
            let gw2 = &mut sb.1[..m * k];
            let g1 = &mut sb.2[..n1 * k];
            let g2 = &mut sb.3[..n2 * k];
            gw.fill(0.0);
            gw2.fill(0.0);
            g1.fill(0.0);
            g2.fill(0.0);
            grads_dense_tiled(
                w, m, ht1, n1, k, v1_blocks[bi * b + bj1].as_slice(),
                model.beta, model.phi, model.mirror, gw, g1, arena,
            );
            grads_dense_tiled(
                w, m, ht2, n2, k, v2_blocks[bi * b + bj2].as_slice(),
                model.beta, model.phi, model.mirror, gw2, g2, arena,
            );
            // W feels both (debiased) data terms
            for (a, &x) in gw.iter_mut().zip(gw2.iter()) {
                *a = scale1 * *a + scale2 * x;
            }
            let mut brng = Rng::derive(seed, &[t, bi as u64, 0xc0]);
            sgld_apply_core(w, gw, eps, 1.0, model.lam_w, model.mirror, &mut brng, arena);
            sgld_apply_core(ht1, g1, eps, scale1, model.lam_h, model.mirror, &mut brng, arena);
            sgld_apply_core(ht2, g2, eps, scale2, model.lam_h, model.mirror, &mut brng, arena);
        });

        // refresh the exposed (W, H1) view in place — no per-step clone
        self.exposed
            .w
            .as_mut_slice()
            .copy_from_slice(self.state.w.as_slice());
        self.exposed
            .ht
            .as_mut_slice()
            .copy_from_slice(self.state.ht1.as_slice());
    }

    fn state(&self) -> &FactorState {
        &self.exposed
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "coupled_psgld"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StepSchedule;
    use crate::rng::Dist;

    fn coupled_data(i: usize, j: usize, l: usize, k: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::seed_from(seed);
        let w = Mat::exponential(i, k, 1.0, &mut rng);
        let h1 = Mat::exponential(k, j, 1.0, &mut rng);
        let h2 = Mat::exponential(k, l, 1.0, &mut rng);
        let mu1 = w.matmul_abs(&h1).unwrap();
        let mu2 = w.matmul_abs(&h2).unwrap();
        let v1 = Mat::from_fn(i, j, |r, c| rng.poisson(mu1.get(r, c) as f64) as f32);
        let v2 = Mat::from_fn(i, l, |r, c| rng.poisson(mu2.get(r, c) as f64) as f32);
        (w, v1, v2)
    }

    #[test]
    fn coupled_improves_joint_loglik() {
        let (_, v1, v2) = coupled_data(24, 24, 18, 4, 1);
        let model = NmfModel::poisson(4);
        let run = RunConfig::quick(300)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
        let mut s = CoupledPsgld::new(&v1, &v2, &model, 3, run, 2).unwrap();
        let before = s.loglik(&v1, &v2);
        for t in 1..=300 {
            s.step(t);
        }
        let after = s.loglik(&v1, &v2);
        assert!(after > before, "{before} -> {after}");
        assert!(s.coupled_state().w.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sharing_w_beats_ignoring_second_matrix_when_v1_scarce() {
        // the whole point of coupling: V2 informs W, improving the fit
        // achievable on V1's held-in data when V1 alone is weak. Proxy:
        // reconstruction of the (noiseless) mu1 from the learned W.
        let mut rng = Rng::seed_from(3);
        let (i, j, l, k) = (24usize, 6usize, 48usize, 3usize);
        let w = Mat::exponential(i, k, 1.0, &mut rng);
        let h1 = Mat::exponential(k, j, 1.0, &mut rng);
        let h2 = Mat::exponential(k, l, 1.0, &mut rng);
        let mu1 = w.matmul_abs(&h1).unwrap();
        let v1 = Mat::from_fn(i, j, |r, c| rng.poisson(mu1.get(r, c) as f64) as f32);
        let mu2 = w.matmul_abs(&h2).unwrap();
        let v2 = Mat::from_fn(i, l, |r, c| rng.poisson(mu2.get(r, c) as f64) as f32);

        let model = NmfModel::poisson(k);
        let run = RunConfig::quick(800)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
        let mut coupled = CoupledPsgld::new(&v1, &v2, &model, 3, run.clone(), 4).unwrap();
        for t in 1..=800 {
            coupled.step(t);
        }
        let rec_c = crate::metrics::rmse_dense(
            &coupled.coupled_state().w,
            &coupled.coupled_state().ht1.transpose(),
            &mu1,
        );

        let mut solo = crate::samplers::Psgld::new(&v1, &model, 3, run.clone(), 4);
        for t in 1..=800 {
            solo.step(t);
        }
        let rec_s =
            crate::metrics::rmse_dense(&solo.state().w, &solo.state().h(), &mu1);
        assert!(
            rec_c < rec_s * 1.05,
            "coupled {rec_c} should beat (or match) solo {rec_s} on scarce V1"
        );
    }

    #[test]
    fn rejects_row_mismatch() {
        let model = NmfModel::poisson(2);
        let v1 = Mat::zeros(8, 8);
        let v2 = Mat::zeros(9, 8);
        assert!(CoupledPsgld::new(&v1, &v2, &model, 2, RunConfig::quick(10), 1).is_err());
    }
}
