//! Gibbs sampler for Poisson-NMF (Cemgil 2009) — the paper's batch-MCMC
//! comparator in Fig. 2(a) / Fig. 3.
//!
//! Model augmentation: `s_ijk ~ Po(w_ik h_kj)`, `v_ij = Σ_k s_ijk`.
//! Full conditionals:
//!   `s_ij· | v, w, h ~ Mult(v_ij, p_k ∝ w_ik h_kj)`
//!   `w_ik | · ~ Gamma(1 + Σ_j s_ijk, 1/(λ_w + Σ_j h_kj))`
//!   `h_kj | · ~ Gamma(1 + Σ_i s_ijk, 1/(λ_h + Σ_i w_ik))`
//!
//! Cost per iteration: a multinomial draw per *observed count* — i.e.
//! `I·J` categorical vectors of size K. This is the `O(IJK)` wall the
//! paper's Fig. 2(a) timing bars show. We accumulate the marginal sums
//! `Σ_j s_ijk`, `Σ_i s_ijk` on the fly instead of materialising the full
//! `I×J×K` tensor (identical chain, identical dominant cost — see
//! DESIGN.md §3).

use crate::linalg::Mat;
use crate::model::NmfModel;
use crate::rng::{Dist, Rng};
use crate::samplers::{FactorState, Sampler};

/// Batch Gibbs sampler for the Poisson-NMF model (β = 1, φ = 1).
pub struct GibbsPoisson {
    v: Mat,
    model: NmfModel,
    state: FactorState,
    rng: Rng,
    // reused accumulators
    sw: Mat,  // I × K: Σ_j s_ijk
    sht: Mat, // J × K: Σ_i s_ijk (transposed layout, like ht)
    weights: Vec<f64>,
    counts: Vec<u64>,
}

impl GibbsPoisson {
    /// `v` must hold non-negative integer counts (Poisson data).
    pub fn new(v: &Mat, model: &NmfModel, seed: u64) -> Self {
        assert_eq!(model.beta, 1.0, "Gibbs requires the Poisson model (beta = 1)");
        assert!(
            v.as_slice().iter().all(|&x| x >= 0.0 && x.fract() == 0.0),
            "Gibbs requires integer count data"
        );
        let mut rng = Rng::derive(seed, &[0x9b5]);
        let state = FactorState::from_prior(model, v.rows(), v.cols(), &mut rng);
        let (i, j, k) = state.shape();
        GibbsPoisson {
            v: v.clone(),
            model: model.clone(),
            state,
            rng,
            sw: Mat::zeros(i, k),
            sht: Mat::zeros(j, k),
            weights: vec![0.0; k],
            counts: vec![0; k],
        }
    }

    pub fn with_state(mut self, state: FactorState) -> Self {
        self.state = state;
        self
    }
}

impl Sampler for GibbsPoisson {
    fn step(&mut self, _t: u64) {
        let (i_rows, j_cols, k) = self.state.shape();

        // ---- S | W, H: multinomial split of every observed count ----
        self.sw.as_mut_slice().fill(0.0);
        self.sht.as_mut_slice().fill(0.0);
        for i in 0..i_rows {
            let wrow = self.state.w.row(i);
            for j in 0..j_cols {
                let v = self.v.get(i, j) as u64;
                if v == 0 {
                    continue;
                }
                let htrow = self.state.ht.row(j);
                for kk in 0..k {
                    self.weights[kk] = (wrow[kk] * htrow[kk]) as f64;
                }
                self.rng.multinomial(v, &self.weights, &mut self.counts);
                let swrow = self.sw.row_mut(i);
                let shtrow = self.sht.row_mut(j);
                for kk in 0..k {
                    let c = self.counts[kk] as f32;
                    swrow[kk] += c;
                    shtrow[kk] += c;
                }
            }
        }

        // ---- W | S, H ----
        // column sums of H: Σ_j h_kj
        let mut hsum = vec![0f64; k];
        for j in 0..j_cols {
            let htrow = self.state.ht.row(j);
            for kk in 0..k {
                hsum[kk] += htrow[kk] as f64;
            }
        }
        for i in 0..i_rows {
            let swrow = self.sw.row(i).to_vec();
            let wrow = self.state.w.row_mut(i);
            for kk in 0..k {
                let shape = 1.0 + swrow[kk] as f64;
                let scale = 1.0 / (self.model.lam_w as f64 + hsum[kk]);
                wrow[kk] = self.rng.gamma(shape, scale) as f32;
            }
        }

        // ---- H | S, W (uses the *new* W) ----
        let mut wsum = vec![0f64; k];
        for i in 0..i_rows {
            let wrow = self.state.w.row(i);
            for kk in 0..k {
                wsum[kk] += wrow[kk] as f64;
            }
        }
        for j in 0..j_cols {
            let shtrow = self.sht.row(j).to_vec();
            let htrow = self.state.ht.row_mut(j);
            for kk in 0..k {
                let shape = 1.0 + shtrow[kk] as f64;
                let scale = 1.0 / (self.model.lam_h as f64 + wsum[kk]);
                htrow[kk] = self.rng.gamma(shape, scale) as f32;
            }
        }
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "gibbs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::synth;
    use crate::samplers::run_sampler;

    #[test]
    fn gibbs_improves_loglik_and_stays_positive() {
        let model = NmfModel::poisson(4);
        let data = synth::poisson_nmf(20, 20, &model, 21);
        let mut g = GibbsPoisson::new(&data.v, &model, 22);
        let run = RunConfig::quick(60);
        let res = run_sampler(&mut g, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
        assert!(res.trace.last_value() > res.trace.values[0]);
        assert!(g.state().w.as_slice().iter().all(|&x| x > 0.0));
        assert!(g.state().ht.as_slice().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gibbs_posterior_mean_reconstructs_data_scale() {
        // after burn-in the reconstruction should be in the data's range
        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(16, 16, &model, 23);
        let mut g = GibbsPoisson::new(&data.v, &model, 24);
        for t in 1..=80 {
            g.step(t);
        }
        let recon = g.state().reconstruct();
        let vmean: f64 =
            data.v.as_slice().iter().map(|&x| x as f64).sum::<f64>() / 256.0;
        let rmean: f64 = recon.as_slice().iter().map(|&x| x as f64).sum::<f64>() / 256.0;
        assert!(
            (rmean - vmean).abs() < 0.35 * vmean,
            "recon mean {rmean} vs data mean {vmean}"
        );
    }

    #[test]
    #[should_panic(expected = "integer count data")]
    fn gibbs_rejects_non_integer_data() {
        let model = NmfModel::poisson(2);
        let v = Mat::from_vec(1, 2, vec![1.5, 2.0]).unwrap();
        GibbsPoisson::new(&v, &model, 1);
    }

    #[test]
    #[should_panic(expected = "beta = 1")]
    fn gibbs_rejects_non_poisson_model() {
        let model = NmfModel::gaussian(2);
        let v = Mat::zeros(2, 2);
        GibbsPoisson::new(&v, &model, 1);
    }
}
