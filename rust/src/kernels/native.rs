//! The L1-equivalent native kernels.
//!
//! Layout convention (hot-path friendly): the dictionary block `W_b` is
//! `m × K` row-major and the weight block is stored **transposed** as
//! `Ht_b = H_b^T` (`n × K` row-major), so the inner loop over K streams
//! two contiguous rows — auto-vectorises to FMA and keeps one row in L1.
//!
//! Every kernel has a raw-slice core (used by the parallel PSGLD driver,
//! which updates disjoint stripes of the factor matrices in place) and a
//! [`Mat`] wrapper for the single-threaded samplers.

use crate::data::sparse::BlockEntries;
use crate::linalg::Mat;
use crate::model::tweedie::{grad_error, loglik_entry, MU_EPS};
use crate::rng::Rng;
use crate::util::parallel::ScratchArena;

/// Gradients of the blockwise log-likelihood plus its value.
#[derive(Clone, Debug)]
pub struct BlockGrads {
    /// d loglik / d W_b — `m × K`.
    pub gw: Mat,
    /// d loglik / d H_b, transposed — `n × K`.
    pub ght: Mat,
    /// Blockwise unnormalised log-likelihood.
    pub ll: f64,
}

impl BlockGrads {
    pub fn zeros(m: usize, n: usize, k: usize) -> Self {
        BlockGrads { gw: Mat::zeros(m, k), ght: Mat::zeros(n, k), ll: 0.0 }
    }
}

/// `jnp.sign` semantics: sign(0) = 0 (matters for exact agreement with
/// the HLO path; `f32::signum` maps 0 to 1).
#[inline]
pub fn sign0(x: f32) -> f32 {
    if x == 0.0 {
        0.0
    } else {
        x.signum()
    }
}

#[inline]
fn dot_abs(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        s += x.abs() * y.abs();
    }
    s
}

/// Accumulate one observed entry's gradient contribution into the
/// per-row accumulators. Returns the entry's log-likelihood.
#[inline]
fn accumulate_entry(
    wrow: &[f32],
    htrow: &[f32],
    v: f32,
    beta: f32,
    phi: f32,
    gwrow: &mut [f32],
    ghtrow: &mut [f32],
) -> f64 {
    let mu = dot_abs(wrow, htrow) + MU_EPS;
    let e = grad_error(v, mu, beta, phi);
    for k in 0..wrow.len() {
        // d mu / d w = sign(w) |h|; d mu / d h = sign(h) |w|
        gwrow[k] += e * sign0(wrow[k]) * htrow[k].abs();
        ghtrow[k] += e * sign0(htrow[k]) * wrow[k].abs();
    }
    loglik_entry(v, mu, beta, phi) as f64
}

/// L1 budget (bytes) for the `k × JB` panel of `|H|ᵀ` a tile streams.
const L1_PANEL_BYTES: usize = 16 * 1024;
/// L1 budget (bytes) for the `IB × JB` error tile.
const L1_ETILE_BYTES: usize = 8 * 1024;

/// Tile shape `(IB, JB)` for [`grads_dense_tiled`]: JB columns so the
/// `k × JB` `|H|ᵀ` panel stays L1-resident, IB rows so the `IB × JB`
/// error tile does too (see EXPERIMENTS.md §Perf for the derivation).
fn tile_shape(k: usize) -> (usize, usize) {
    let jb = (L1_PANEL_BYTES / 4 / k.max(1)).clamp(32, 256);
    let ib = (L1_ETILE_BYTES / 4 / jb).clamp(8, 64);
    (ib, jb)
}

/// Instantiate the tiled dense kernel body against one SIMD ops module.
/// The scalar and AVX2 instantiations share this single source of truth,
/// and because the ops modules implement one canonical arithmetic order
/// (see `kernels::simd`), the two instantiations are bitwise identical.
macro_rules! dense_tiled_kernel {
    ($(#[$attr:meta])* $name:ident, $ops:path) => {
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        unsafe fn $name(
            w: &[f32],
            m: usize,
            ht: &[f32],
            n: usize,
            k: usize,
            v: &[f32],
            beta: f32,
            phi: f32,
            nonneg: bool,
            gw: &mut [f32],
            ght: &mut [f32],
            scratch: &mut ScratchArena,
        ) -> f64 {
            use $ops as ops;
            let (ib, jb) = tile_shape(k);
            let (wabs_buf, habs_t, etile) =
                scratch.take3(if nonneg { 0 } else { m * k }, k * n, ib * jb);

            // |W| (m×k); the fast path reads w directly (|x| = x).
            let wa: &[f32] = if nonneg {
                w
            } else {
                for (dst, &x) in wabs_buf.iter_mut().zip(w.iter()) {
                    *dst = x.abs();
                }
                wabs_buf
            };
            // |H| stored K-major (k×n): habs_t[kk*n + j] = |ht[j*k + kk]|.
            // One transposed copy per block so every inner loop streams
            // contiguously.
            for kk in 0..k {
                let row = &mut habs_t[kk * n..(kk + 1) * n];
                for (j, dst) in row.iter_mut().enumerate() {
                    let x = ht[j * k + kk];
                    *dst = if nonneg { x } else { x.abs() };
                }
            }

            let mut ll = 0.0f64;
            let mut i0 = 0;
            while i0 < m {
                let mi = (i0 + ib).min(m) - i0;
                let mut j0 = 0;
                while j0 < n {
                    let nj = (j0 + jb).min(n) - j0;

                    // mu tile:
                    // E[ii][jj] = MU_EPS + Σ_kk |W|[i0+ii][kk] |H|[kk][j0+jj],
                    // four K-streams at a time (rank-4 row update)
                    for ii in 0..mi {
                        let erow = &mut etile[ii * nj..(ii + 1) * nj];
                        erow.fill(MU_EPS);
                        let warow = &wa[(i0 + ii) * k..(i0 + ii) * k + k];
                        let mut kk = 0;
                        while kk + 4 <= k {
                            let a = [warow[kk], warow[kk + 1], warow[kk + 2], warow[kk + 3]];
                            let h0 = &habs_t[kk * n + j0..kk * n + j0 + nj];
                            let h1 = &habs_t[(kk + 1) * n + j0..(kk + 1) * n + j0 + nj];
                            let h2 = &habs_t[(kk + 2) * n + j0..(kk + 2) * n + j0 + nj];
                            let h3 = &habs_t[(kk + 3) * n + j0..(kk + 3) * n + j0 + nj];
                            ops::fma4(erow, a, h0, h1, h2, h3);
                            kk += 4;
                        }
                        while kk < k {
                            let a = warow[kk];
                            let hrow = &habs_t[kk * n + j0..kk * n + j0 + nj];
                            ops::axpy(erow, a, hrow);
                            kk += 1;
                        }
                    }

                    // ll + error transform in place, while the tile is L1-hot
                    for ii in 0..mi {
                        let erow = &mut etile[ii * nj..(ii + 1) * nj];
                        let vrow = &v[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nj];
                        for (ev, &vv) in erow.iter_mut().zip(vrow.iter()) {
                            let mu = *ev;
                            ll += loglik_entry(vv, mu, beta, phi) as f64;
                            *ev = grad_error(vv, mu, beta, phi);
                        }
                    }

                    // GW[i][kk] += Σ_jj E[ii][jj] |H|[kk][j0+jj]
                    for ii in 0..mi {
                        let erow = &etile[ii * nj..(ii + 1) * nj];
                        let gwrow = &mut gw[(i0 + ii) * k..(i0 + ii) * k + k];
                        for (kk, g) in gwrow.iter_mut().enumerate() {
                            let hrow = &habs_t[kk * n + j0..kk * n + j0 + nj];
                            *g += ops::dot(erow, hrow);
                        }
                    }

                    // GHt[j][kk] += Σ_ii E[ii][jj] |W|[i0+ii][kk]
                    for ii in 0..mi {
                        let erow = &etile[ii * nj..(ii + 1) * nj];
                        let warow = &wa[(i0 + ii) * k..(i0 + ii) * k + k];
                        for (jj, &ev) in erow.iter().enumerate() {
                            let ghtrow = &mut ght[(j0 + jj) * k..(j0 + jj) * k + k];
                            ops::axpy(ghtrow, ev, warow);
                        }
                    }
                    j0 += nj;
                }
                i0 += mi;
            }

            // sign corrections, once at the end over the accumulated
            // totals; exact because sign ∈ {-1, 0, 1} distributes over
            // the summed accumulator
            if nonneg {
                ops::zero_kill(gw, w);
                ops::zero_kill(ght, ht);
            } else {
                ops::scale_by_sign(gw, w);
                ops::scale_by_sign(ght, ht);
            }
            ll
        }
    };
}

dense_tiled_kernel!(dense_tiled_scalar, crate::kernels::simd::scalar);
dense_tiled_kernel!(
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    dense_tiled_avx2,
    crate::kernels::simd::avx2
);

/// Cache-tiled, allocation-free dense block gradients — the PSGLD hot
/// path. `w` is `m×k`, `ht` is `n×k`, `v` is `m×n`, all row-major;
/// `gw`/`ght` are **zeroed** accumulators of matching size; temporaries
/// live in `scratch` (grow-only, so the steady state allocates nothing).
/// Returns the blockwise log-likelihood.
///
/// `nonneg` is the mirror fast path: when the caller guarantees
/// `w, ht ≥ 0` (the mirroring step keeps the exponential-prior state
/// non-negative), `|x| = x` and `sign(x) ∈ {0, 1}`, so the `|W|` copy
/// and per-entry sign multiplies collapse to a final zero-kill. The two
/// paths are bitwise identical on non-negative inputs.
///
/// §Perf: instead of three full GEMM-shaped passes over an `m×n` error
/// buffer, the work is fused per `IB × JB` tile — mu (rank-4 K loop) →
/// elementwise ll/E → both rank-updates — while the error tile is still
/// L1-hot. The inner loops dispatch once per call to the AVX2+FMA tier
/// when the CPU has it; the scalar tier computes the identical bits
/// (see `kernels::simd`). Before/after numbers in EXPERIMENTS.md §Perf.
#[allow(clippy::too_many_arguments)]
pub fn grads_dense_tiled(
    w: &[f32],
    m: usize,
    ht: &[f32],
    n: usize,
    k: usize,
    v: &[f32],
    beta: f32,
    phi: f32,
    nonneg: bool,
    gw: &mut [f32],
    ght: &mut [f32],
    scratch: &mut ScratchArena,
) -> f64 {
    debug_assert_eq!(w.len(), m * k);
    debug_assert_eq!(ht.len(), n * k);
    debug_assert_eq!(v.len(), m * n);
    debug_assert_eq!(gw.len(), m * k);
    debug_assert_eq!(ght.len(), n * k);
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::simd::active_tier() == crate::kernels::simd::SimdTier::Avx2Fma {
            // SAFETY: the Avx2Fma tier is only active when runtime
            // detection (or an explicit, caller-guarded override) says
            // the CPU has AVX2+FMA.
            return unsafe {
                dense_tiled_avx2(w, m, ht, n, k, v, beta, phi, nonneg, gw, ght, scratch)
            };
        }
    }
    // SAFETY: the scalar instantiation contains no unsafe operations;
    // it is `unsafe fn` only for signature parity with the AVX2 twin.
    unsafe { dense_tiled_scalar(w, m, ht, n, k, v, beta, phi, nonneg, gw, ght, scratch) }
}

/// Slice-core dense block gradients — convenience wrapper over
/// [`grads_dense_tiled`] (no non-negativity assumption). Temporaries
/// come from the calling thread's private grow-only arena
/// (`with_thread_scratch`), so repeated one-shot calls are
/// allocation-free in the steady state, like the pool path with its
/// per-worker arenas.
#[allow(clippy::too_many_arguments)]
pub fn grads_dense_core(
    w: &[f32],
    m: usize,
    ht: &[f32],
    n: usize,
    k: usize,
    v: &[f32],
    beta: f32,
    phi: f32,
    gw: &mut [f32],
    ght: &mut [f32],
) -> f64 {
    crate::util::parallel::with_thread_scratch(|scratch| {
        grads_dense_tiled(w, m, ht, n, k, v, beta, phi, false, gw, ght, scratch)
    })
}

/// Instantiate the CSR sparse kernel body against one SIMD ops module
/// (same single-source scheme as [`dense_tiled_kernel`]).
macro_rules! sparse_csr_kernel {
    ($(#[$attr:meta])* $name:ident, $ops:path) => {
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        unsafe fn $name(
            w: &[f32],
            ht: &[f32],
            k: usize,
            blk: &BlockEntries,
            beta: f32,
            phi: f32,
            nonneg: bool,
            gw: &mut [f32],
            ght: &mut [f32],
        ) -> f64 {
            use $ops as ops;
            let indptr = blk.indptr();
            let cols = blk.cols();
            let vals = blk.vals();
            let mut ll = 0.0f64;
            if nonneg {
                for i in 0..blk.nrows() {
                    let s = indptr[i] as usize;
                    let e = indptr[i + 1] as usize;
                    if s == e {
                        continue;
                    }
                    // row i's W row and gw accumulator stay hot across
                    // all of the row's entries (the CSR layout payoff)
                    let wrow = &w[i * k..(i + 1) * k];
                    let gwrow = &mut gw[i * k..(i + 1) * k];
                    for idx in s..e {
                        let j = cols[idx] as usize;
                        let htrow = &ht[j * k..(j + 1) * k];
                        let mu = ops::dot(wrow, htrow) + MU_EPS;
                        let v = vals[idx];
                        let err = grad_error(v, mu, beta, phi);
                        ll += loglik_entry(v, mu, beta, phi) as f64;
                        ops::axpy2(err, htrow, wrow, gwrow, &mut ght[j * k..(j + 1) * k]);
                    }
                }
                // exact zeros have sign 0: kill their (measure-zero) gradient
                ops::zero_kill(gw, w);
                ops::zero_kill(ght, ht);
            } else {
                for i in 0..blk.nrows() {
                    let s = indptr[i] as usize;
                    let e = indptr[i + 1] as usize;
                    if s == e {
                        continue;
                    }
                    let wrow = &w[i * k..(i + 1) * k];
                    let gwrow = &mut gw[i * k..(i + 1) * k];
                    for idx in s..e {
                        let j = cols[idx] as usize;
                        let htrow = &ht[j * k..(j + 1) * k];
                        let mu = ops::dot_abs(wrow, htrow) + MU_EPS;
                        let v = vals[idx];
                        let err = grad_error(v, mu, beta, phi);
                        ll += loglik_entry(v, mu, beta, phi) as f64;
                        // accumulate against |h| / |w|; the sign factors
                        // are applied once below — exact, since
                        // sign ∈ {-1, 0, 1} distributes over the sum
                        ops::axpy2_abs(err, htrow, wrow, gwrow, &mut ght[j * k..(j + 1) * k]);
                    }
                }
                ops::scale_by_sign(gw, w);
                ops::scale_by_sign(ght, ht);
            }
            ll
        }
    };
}

sparse_csr_kernel!(sparse_csr_scalar, crate::kernels::simd::scalar);
sparse_csr_kernel!(
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    sparse_csr_avx2,
    crate::kernels::simd::avx2
);

/// Decide the sparse kernel's `nonneg` fast path **once per part**: the
/// mirror flag settles it for free; otherwise scan the factors only when
/// the per-entry work it saves (`nnz·K`) exceeds the scan cost. Callers
/// (the samplers and the cluster simulator) must all use this helper so
/// shared-memory and distributed chains stay bitwise identical.
pub fn nonneg_hint(mirror: bool, w: &[f32], ht: &[f32], nnz: usize) -> bool {
    mirror
        || (nnz > w.len() + ht.len()
            && w.iter().all(|&x| x >= 0.0)
            && ht.iter().all(|&x| x >= 0.0))
}

/// Slice-core sparse block gradients over a block-local CSR block.
///
/// §Perf: the CSR walk keeps each observed row's `W` row and `gw`
/// accumulator register/L1-hot across all the row's entries, and the
/// K-loops dispatch to the AVX2+FMA tier (8-lane dot + fused axpy pair)
/// when available — with a bitwise-identical scalar fallback. `nonneg`
/// is authoritative here: callers hoist the decision to once per part
/// via [`nonneg_hint`] instead of rescanning the factors per block.
#[allow(clippy::too_many_arguments)]
pub fn grads_sparse_core(
    w: &[f32],
    ht: &[f32],
    k: usize,
    blk: &BlockEntries,
    beta: f32,
    phi: f32,
    nonneg: bool,
    gw: &mut [f32],
    ght: &mut [f32],
) -> f64 {
    debug_assert_eq!(gw.len(), w.len());
    debug_assert_eq!(ght.len(), ht.len());
    debug_assert!(blk.nrows() * k <= w.len());
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::simd::active_tier() == crate::kernels::simd::SimdTier::Avx2Fma {
            // SAFETY: Avx2Fma is only active on CPUs with AVX2+FMA (see
            // `grads_dense_tiled`).
            return unsafe { sparse_csr_avx2(w, ht, k, blk, beta, phi, nonneg, gw, ght) };
        }
    }
    // SAFETY: no unsafe operations in the scalar instantiation.
    unsafe { sparse_csr_scalar(w, ht, k, blk, beta, phi, nonneg, gw, ght) }
}

/// The pre-CSR scalar reference: a per-entry walk over explicit COO
/// triples, kept verbatim as (a) the oracle for the CSR/SIMD
/// equivalence tests and (b) the "before" baseline of the fig5
/// microbench. Feed it `BlockEntries::iter_coo()` output.
#[allow(clippy::too_many_arguments)]
pub fn grads_sparse_coo_ref(
    w: &[f32],
    ht: &[f32],
    k: usize,
    rows: &[u32],
    cols: &[u32],
    vals: &[f32],
    beta: f32,
    phi: f32,
    nonneg: bool,
    gw: &mut [f32],
    ght: &mut [f32],
) -> f64 {
    let nonneg = nonneg
        || (vals.len() > w.len() + ht.len()
            && w.iter().all(|&x| x >= 0.0)
            && ht.iter().all(|&x| x >= 0.0));
    let mut ll = 0.0f64;
    if nonneg {
        for idx in 0..vals.len() {
            let i = rows[idx] as usize;
            let j = cols[idx] as usize;
            let wrow = &w[i * k..(i + 1) * k];
            let htrow = &ht[j * k..(j + 1) * k];
            let mut mu = MU_EPS;
            for (&a, &b) in wrow.iter().zip(htrow.iter()) {
                mu += a * b;
            }
            let e = grad_error(vals[idx], mu, beta, phi);
            ll += loglik_entry(vals[idx], mu, beta, phi) as f64;
            let gwrow = &mut gw[i * k..(i + 1) * k];
            let ghtrow = &mut ght[j * k..(j + 1) * k];
            for ((g, &hv), (gh, &wv)) in gwrow
                .iter_mut()
                .zip(htrow.iter())
                .zip(ghtrow.iter_mut().zip(wrow.iter()))
            {
                *g += e * hv;
                *gh += e * wv;
            }
        }
        for (g, &x) in gw.iter_mut().zip(w.iter()) {
            if x == 0.0 {
                *g = 0.0;
            }
        }
        for (g, &x) in ght.iter_mut().zip(ht.iter()) {
            if x == 0.0 {
                *g = 0.0;
            }
        }
        return ll;
    }
    for idx in 0..vals.len() {
        let i = rows[idx] as usize;
        let j = cols[idx] as usize;
        ll += accumulate_entry(
            &w[i * k..(i + 1) * k],
            &ht[j * k..(j + 1) * k],
            vals[idx],
            beta,
            phi,
            &mut gw[i * k..(i + 1) * k],
            &mut ght[j * k..(j + 1) * k],
        );
    }
    ll
}

/// Row-stripe length of the SGLD noise slab: 8 KiB of f32 — large
/// enough to amortise the ziggurat refill, small enough to stay L1-hot
/// alongside the `x`/`g` stripes it is fused with.
pub const NOISE_STRIPE: usize = 2048;

/// Slice-core SGLD step:
/// `x += eps * (scale * g - lam * sign(x)) + N(0, 2 eps)`, then the
/// optional mirroring `x = |x|` (paper Eqs. 8-9 + §3.2).
///
/// §Perf: noise is drawn in batches — `fill_normal_ziggurat` refills a
/// `scratch`-backed slab of [`NOISE_STRIPE`] draws per row-stripe, so
/// the update loop itself is a branch-free fused pass over contiguous
/// slices (the ziggurat's rare wedge/tail branches stay out of it). The
/// slab consumes the RNG stream exactly like the old per-element draw
/// did, so chains keep the (seed, t, block)-keyed draw order and remain
/// bitwise reproducible across ExecMode and worker counts — and across
/// this PR. Allocation-free once `scratch` reaches its high-water mark.
#[allow(clippy::too_many_arguments)]
pub fn sgld_apply_core(
    x: &mut [f32],
    g: &[f32],
    eps: f32,
    scale: f32,
    lam: f32,
    mirror: bool,
    rng: &mut Rng,
    scratch: &mut ScratchArena,
) {
    debug_assert_eq!(x.len(), g.len());
    let sd = (2.0 * eps).sqrt();
    let n = x.len();
    let slab = scratch.take(n.min(NOISE_STRIPE));
    let mut start = 0;
    while start < n {
        let len = (n - start).min(NOISE_STRIPE);
        let noise = &mut slab[..len];
        crate::rng::fill_normal_ziggurat(rng, noise);
        let xs = &mut x[start..start + len];
        let gs = &g[start..start + len];
        if mirror {
            for i in 0..len {
                let next = xs[i] + eps * (scale * gs[i] - lam * sign0(xs[i])) + noise[i] * sd;
                xs[i] = next.abs();
            }
        } else {
            for i in 0..len {
                let next = xs[i] + eps * (scale * gs[i] - lam * sign0(xs[i])) + noise[i] * sd;
                xs[i] = next;
            }
        }
        start += len;
    }
}

/// Noise-free (SGD) variant — the DSGD baseline's update.
pub fn sgd_apply_core(x: &mut [f32], g: &[f32], eps: f32, scale: f32, lam: f32, mirror: bool) {
    debug_assert_eq!(x.len(), g.len());
    for idx in 0..x.len() {
        let xv = x[idx];
        let next = xv + eps * (scale * g[idx] - lam * sign0(xv));
        x[idx] = if mirror { next.abs() } else { next };
    }
}

// ---------------------------------------------------------------------------
// Mat wrappers
// ---------------------------------------------------------------------------

/// Dense block gradients: every `(i, j)` of `v` is observed.
pub fn dense_block_grads(w: &Mat, ht: &Mat, v: &Mat, beta: f32, phi: f32) -> BlockGrads {
    let (m, k) = w.shape();
    let (n, k2) = ht.shape();
    assert_eq!(k, k2);
    assert_eq!(v.shape(), (m, n));
    let mut out = BlockGrads::zeros(m, n, k);
    out.ll = grads_dense_core(
        w.as_slice(),
        m,
        ht.as_slice(),
        n,
        k,
        v.as_slice(),
        beta,
        phi,
        out.gw.as_mut_slice(),
        out.ght.as_mut_slice(),
    );
    out
}

/// Sparse block gradients: only the block's stored entries contribute.
pub fn sparse_block_grads(
    w: &Mat,
    ht: &Mat,
    blk: &BlockEntries,
    beta: f32,
    phi: f32,
) -> BlockGrads {
    let (m, k) = w.shape();
    let n = ht.rows();
    let mut out = BlockGrads::zeros(m, n, k);
    let hint = nonneg_hint(false, w.as_slice(), ht.as_slice(), blk.nnz());
    out.ll = grads_sparse_core(
        w.as_slice(),
        ht.as_slice(),
        k,
        blk,
        beta,
        phi,
        hint,
        out.gw.as_mut_slice(),
        out.ght.as_mut_slice(),
    );
    out
}

/// Apply the SGLD step to one factor block in place (Mat wrapper). The
/// noise slab comes from the calling thread's private arena, so the
/// signature stays scratch-free for the single-threaded samplers.
pub fn sgld_apply(
    x: &mut Mat,
    g: &Mat,
    eps: f32,
    scale: f32,
    lam: f32,
    mirror: bool,
    rng: &mut Rng,
) {
    debug_assert_eq!(x.shape(), g.shape());
    crate::util::parallel::with_thread_scratch(|scratch| {
        sgld_apply_core(x.as_mut_slice(), g.as_slice(), eps, scale, lam, mirror, rng, scratch);
    });
}

/// Noise-free (SGD) step (Mat wrapper).
pub fn sgd_apply(x: &mut Mat, g: &Mat, eps: f32, scale: f32, lam: f32, mirror: bool) {
    debug_assert_eq!(x.shape(), g.shape());
    sgd_apply_core(x.as_mut_slice(), g.as_slice(), eps, scale, lam, mirror);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::rng::Rng;

    fn setup(m: usize, n: usize, k: usize) -> (Mat, Mat, Mat) {
        let mut rng = Rng::seed_from(1);
        let w = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let ht = Mat::uniform(n, k, 0.1, 1.0, &mut rng);
        let v = Mat::from_fn(m, n, |i, j| ((i * 7 + j * 3) % 5) as f32);
        (w, ht, v)
    }

    /// GEMM-style reference: G_W = E |H|^T, G_H = |W|^T E.
    fn gemm_reference(w: &Mat, ht: &Mat, v: &Mat, beta: f32, phi: f32) -> BlockGrads {
        let h = ht.transpose();
        let mu = w.matmul_abs(&h).unwrap();
        let (m, n) = v.shape();
        let k = w.cols();
        let mut out = BlockGrads::zeros(m, n, k);
        for i in 0..m {
            for j in 0..n {
                let muv = mu.get(i, j) + MU_EPS;
                let e = grad_error(v.get(i, j), muv, beta, phi);
                out.ll += loglik_entry(v.get(i, j), muv, beta, phi) as f64;
                for kk in 0..k {
                    let wv = w.get(i, kk);
                    let hv = ht.get(j, kk);
                    out.gw.as_mut_slice()[i * k + kk] += e * sign0(wv) * hv.abs();
                    out.ght.as_mut_slice()[j * k + kk] += e * sign0(hv) * wv.abs();
                }
            }
        }
        out
    }

    #[test]
    fn dense_grads_match_reference_all_betas() {
        let (w, ht, v) = setup(16, 12, 4);
        for &beta in &[0.0f32, 0.5, 1.0, 2.0] {
            let a = dense_block_grads(&w, &ht, &v, beta, 1.0);
            let b = gemm_reference(&w, &ht, &v, beta, 1.0);
            assert!((a.ll - b.ll).abs() < 1e-4, "beta {beta}");
            assert!(a.gw.frob_dist(&b.gw) < 1e-4);
            assert!(a.ght.frob_dist(&b.ght) < 1e-4);
        }
    }

    #[test]
    fn sparse_on_full_pattern_equals_dense() {
        let (w, ht, v) = setup(10, 8, 3);
        let mut trip: Vec<(u32, u32, f32)> = Vec::new();
        for i in 0..10 {
            for j in 0..8 {
                trip.push((i as u32, j as u32, v.get(i, j)));
            }
        }
        let csr = Csr::from_triplets(10, 8, &mut trip).unwrap();
        let bs = crate::data::BlockedSparse::from_csr(&csr, 1).unwrap();
        let a = dense_block_grads(&w, &ht, &v, 1.0, 1.0);
        let b = sparse_block_grads(&w, &ht, bs.block(0, 0), 1.0, 1.0);
        assert!((a.ll - b.ll).abs() < 1e-4);
        assert!(a.gw.frob_dist(&b.gw) < 1e-3);
        assert!(a.ght.frob_dist(&b.ght) < 1e-3);
    }

    #[test]
    fn sign_zero_kills_gradient() {
        let mut w = Mat::zeros(2, 2);
        w.set(0, 0, 0.5); // only one live entry
        let ht = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let v = Mat::from_vec(2, 2, vec![3.0, 3.0, 3.0, 3.0]).unwrap();
        let g = dense_block_grads(&w, &ht, &v, 1.0, 1.0);
        // rows of W that are zero get zero W-gradient
        assert_eq!(g.gw.get(1, 0), 0.0);
        assert_eq!(g.gw.get(1, 1), 0.0);
        assert!(g.gw.get(0, 0) != 0.0);
    }

    #[test]
    fn sgld_apply_noise_variance() {
        // zero gradient, zero prior: pure N(0, 2 eps) noise
        let mut rng = Rng::seed_from(2);
        let eps = 0.02f32;
        let g = Mat::zeros(201, 101); // odd total exercises the tail
        let mut x = Mat::zeros(201, 101);
        sgld_apply(&mut x, &g, eps, 1.0, 0.0, false, &mut rng);
        let n = (201 * 101) as f64;
        let mean: f64 = x.as_slice().iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 =
            x.as_slice().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n;
        assert!(mean.abs() < 0.005, "{mean}");
        assert!((var - 2.0 * eps as f64).abs() < 0.003, "{var}");
    }

    #[test]
    fn sgld_apply_mirror_nonnegative() {
        let mut rng = Rng::seed_from(3);
        let g = Mat::zeros(50, 50);
        let mut x = Mat::zeros(50, 50);
        sgld_apply(&mut x, &g, 0.5, 1.0, 0.0, true, &mut rng);
        assert!(x.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn sgld_drift_matches_formula_when_noise_free_limit() {
        // compare against manual drift with eps -> small and fixed seed
        // by subtracting two runs that share the same rng stream
        let (w, _, _) = setup(6, 6, 3);
        let g = Mat::from_fn(6, 3, |i, j| (i + j) as f32);
        let eps = 1e-3f32;
        let mut a = w.clone();
        let mut rng1 = Rng::seed_from(9);
        sgld_apply(&mut a, &g, eps, 2.0, 0.5, false, &mut rng1);
        let mut noise_only = w.clone();
        let zero = Mat::zeros(6, 3);
        let mut rng2 = Rng::seed_from(9);
        sgld_apply(&mut noise_only, &zero, eps, 0.0, 0.0, false, &mut rng2);
        for idx in 0..18 {
            let drift = a.as_slice()[idx] - noise_only.as_slice()[idx];
            let expect = eps
                * (2.0 * g.as_slice()[idx] - 0.5 * sign0(w.as_slice()[idx]));
            assert!((drift - expect).abs() < 1e-6, "idx {idx}");
        }
    }

    #[test]
    fn tiled_matches_reference_at_tile_boundaries() {
        // shapes straddling the IB/JB edges (tile_shape(6) = clamped
        // values well below these dims) exercise partial tiles on both
        // axes, including single-row / single-column remainders.
        for &(m, n) in &[(1usize, 1usize), (7, 260), (65, 257), (40, 33)] {
            let (w, ht, v) = setup(m, n, 6);
            let a = dense_block_grads(&w, &ht, &v, 1.0, 1.0);
            let b = gemm_reference(&w, &ht, &v, 1.0, 1.0);
            assert!((a.ll - b.ll).abs() < 1e-3 * (m * n) as f64, "{m}x{n}");
            assert!(a.gw.frob_dist(&b.gw) < 1e-3, "{m}x{n}");
            assert!(a.ght.frob_dist(&b.ght) < 1e-3, "{m}x{n}");
        }
    }

    #[test]
    fn tiled_nonneg_fast_path_is_bitwise_identical() {
        // setup() draws from U(0.1, 1.0) so the inputs are strictly
        // positive; the fast path must agree bit-for-bit, not just
        // within tolerance.
        let (w, ht, v) = setup(33, 41, 5);
        let (m, n, k) = (33, 41, 5);
        let mut scratch = ScratchArena::new();
        let mut gw_a = vec![0f32; m * k];
        let mut ght_a = vec![0f32; n * k];
        let ll_a = grads_dense_tiled(
            w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(),
            1.0, 1.0, false, &mut gw_a, &mut ght_a, &mut scratch,
        );
        let mut gw_b = vec![0f32; m * k];
        let mut ght_b = vec![0f32; n * k];
        let ll_b = grads_dense_tiled(
            w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(),
            1.0, 1.0, true, &mut gw_b, &mut ght_b, &mut scratch,
        );
        assert_eq!(ll_a, ll_b);
        assert_eq!(gw_a, gw_b);
        assert_eq!(ght_a, ght_b);
    }

    #[test]
    fn tiled_is_stable_under_arena_reuse() {
        // the arena hands back uninitialised (stale) memory; a second
        // call with a dirty arena must still produce identical output
        let (w, ht, v) = setup(20, 24, 4);
        let (m, n, k) = (20, 24, 4);
        let mut scratch = ScratchArena::new();
        let run = |scratch: &mut ScratchArena| {
            let mut gw = vec![0f32; m * k];
            let mut ght = vec![0f32; n * k];
            let ll = grads_dense_tiled(
                w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(),
                0.5, 1.0, false, &mut gw, &mut ght, scratch,
            );
            (ll, gw, ght)
        };
        let first = run(&mut scratch);
        let second = run(&mut scratch);
        assert_eq!(first.0, second.0);
        assert_eq!(first.1, second.1);
        assert_eq!(first.2, second.2);
    }

    #[test]
    fn sparse_nonneg_hint_matches_unhinted() {
        let (w, ht, v) = setup(12, 9, 3);
        let mut trip: Vec<(u32, u32, f32)> = Vec::new();
        for i in 0..12u32 {
            for j in 0..9u32 {
                if (i + j) % 3 == 0 {
                    trip.push((i, j, v.get(i as usize, j as usize)));
                }
            }
        }
        let csr = Csr::from_triplets(12, 9, &mut trip).unwrap();
        let bs = crate::data::BlockedSparse::from_csr(&csr, 1).unwrap();
        let blk = bs.block(0, 0);
        let k = 3;
        let run = |hint: bool| {
            let mut gw = vec![0f32; 12 * k];
            let mut ght = vec![0f32; 9 * k];
            let ll = grads_sparse_core(
                w.as_slice(), ht.as_slice(), k, blk, 1.0, 1.0, hint,
                &mut gw, &mut ght,
            );
            (ll, gw, ght)
        };
        // strictly positive inputs: hinted fast path vs the generic
        // per-entry path must agree to tolerance (the hint only changes
        // which inner loop runs, not what it computes)
        let (ll_h, gw_h, ght_h) = run(true);
        let (ll_u, gw_u, ght_u) = run(false);
        assert!((ll_h - ll_u).abs() < 1e-6);
        for (a, b) in gw_h.iter().zip(gw_u.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in ght_h.iter().zip(ght_u.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn sgd_apply_is_deterministic_gradient_ascent() {
        let (w, ht, v) = setup(8, 8, 2);
        let mut model_ll_before = 0.0;
        let mut w1 = w.clone();
        for step in 0..5 {
            let g = dense_block_grads(&w1, &ht, &v, 2.0, 1.0);
            if step == 0 {
                model_ll_before = g.ll;
            }
            sgd_apply(&mut w1, &g.gw, 1e-3, 1.0, 0.0, true);
        }
        let after = dense_block_grads(&w1, &ht, &v, 2.0, 1.0).ll;
        assert!(after > model_ll_before, "{after} vs {model_ll_before}");
    }
}
