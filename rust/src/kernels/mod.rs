//! Native (pure-Rust) compute kernels: the block gradient + SGLD update
//! hot path used by the shared-memory samplers, the sparse (MovieLens)
//! path, and the cluster simulator's full-fidelity mode.
//!
//! The HLO/Pallas path (`runtime`) covers the dense batched part update;
//! these natives must agree with it numerically (see
//! `rust/tests/runtime_roundtrip.rs`).

pub mod native;
pub mod simd;

pub use native::{
    dense_block_grads, grads_dense_core, grads_dense_tiled, grads_sparse_coo_ref,
    grads_sparse_core, nonneg_hint, sgd_apply, sgd_apply_core, sgld_apply,
    sgld_apply_core, sign0, sparse_block_grads, BlockGrads,
};
pub use simd::{active_tier, avx2_available, set_tier_override, SimdTier};
