//! SIMD dispatch layer for the kernel hot paths.
//!
//! Two tiers implement one **canonical arithmetic order**:
//!
//! * [`avx2`] — explicit AVX2+FMA intrinsics: 8-lane f32 dot products,
//!   fused `e·h`/`e·w` axpy pairs, and vectorised sign/zero fixups.
//! * [`scalar`] — a portable fallback that mimics the SIMD arithmetic
//!   exactly: 8 independent accumulator lanes combined in the same
//!   reduction tree, with `f32::mul_add` wherever the AVX2 tier issues
//!   an FMA. Both tiers are **bitwise identical** on every input the
//!   samplers produce (asserted in `rust/tests/simd_csr.rs`), which is
//!   what keeps chains reproducible across machines with and without
//!   AVX2.
//!
//! The active tier is chosen once at runtime via
//! `is_x86_feature_detected!` (overridable with `PALLAS_SIMD=scalar`
//! in the environment, or programmatically with [`set_tier_override`]
//! — a test/bench hook). Kernels read [`active_tier`] once per call and
//! branch to a fully monomorphised loop, so dispatch costs nothing per
//! entry.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Instruction tier a kernel body runs with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable fallback (canonical-order `mul_add` loops).
    Scalar,
    /// AVX2 + FMA intrinsics (x86-64, runtime-detected).
    Avx2Fma,
}

const OVERRIDE_NONE: u8 = u8::MAX;
static OVERRIDE: AtomicU8 = AtomicU8::new(OVERRIDE_NONE);
static DETECTED: OnceLock<SimdTier> = OnceLock::new();

/// True when this CPU supports the AVX2+FMA tier.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect() -> SimdTier {
    if let Ok(v) = std::env::var("PALLAS_SIMD") {
        if matches!(v.trim().to_ascii_lowercase().as_str(), "scalar" | "off" | "0") {
            return SimdTier::Scalar;
        }
    }
    if avx2_available() {
        SimdTier::Avx2Fma
    } else {
        SimdTier::Scalar
    }
}

/// The tier kernels dispatch to. Detection runs once; an override (test
/// hook) takes precedence. Because the tiers are bitwise identical,
/// flipping the override at any point never changes numerical results.
pub fn active_tier() -> SimdTier {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => SimdTier::Scalar,
        1 => SimdTier::Avx2Fma,
        _ => *DETECTED.get_or_init(detect),
    }
}

/// Force a tier (tests/benches only; `None` restores auto-detection).
/// Forcing `Avx2Fma` on a CPU without AVX2+FMA is undefined behaviour —
/// guard with [`avx2_available`].
pub fn set_tier_override(tier: Option<SimdTier>) {
    let v = match tier {
        None => OVERRIDE_NONE,
        Some(SimdTier::Scalar) => 0,
        Some(SimdTier::Avx2Fma) => 1,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// Portable canonical-order implementations. Every function here is the
/// bitwise reference for its [`avx2`] twin: 8 accumulator lanes, the
/// same reduction tree, `mul_add` for each fused multiply-add.
pub mod scalar {
    /// Reduction tree shared with the AVX2 horizontal sum:
    /// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`.
    #[inline]
    pub(super) fn reduce8(l: [f32; 8]) -> f32 {
        let s04 = l[0] + l[4];
        let s15 = l[1] + l[5];
        let s26 = l[2] + l[6];
        let s37 = l[3] + l[7];
        (s04 + s26) + (s15 + s37)
    }

    /// 8-lane dot product: lane `j` accumulates elements `j, j+8, ...`
    /// with FMA; lanes reduce via [`reduce8`]; the tail (`len % 8`)
    /// folds in sequentially.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut l = [0f32; 8];
        for c in 0..chunks {
            let i = c * 8;
            for j in 0..8 {
                l[j] = a[i + j].mul_add(b[i + j], l[j]);
            }
        }
        let mut s = reduce8(l);
        for i in chunks * 8..n {
            s = a[i].mul_add(b[i], s);
        }
        s
    }

    /// [`dot`] over `|a|·|b|` (the generic mu accumulation).
    #[inline]
    pub fn dot_abs(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut l = [0f32; 8];
        for c in 0..chunks {
            let i = c * 8;
            for j in 0..8 {
                l[j] = a[i + j].abs().mul_add(b[i + j].abs(), l[j]);
            }
        }
        let mut s = reduce8(l);
        for i in chunks * 8..n {
            s = a[i].abs().mul_add(b[i].abs(), s);
        }
        s
    }

    /// `y[i] += a * x[i]` (FMA per element).
    #[inline]
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for (yv, &xv) in y.iter_mut().zip(x.iter()) {
            *yv = a.mul_add(xv, *yv);
        }
    }

    /// Fused gradient pair for one observed entry:
    /// `gw[i] += e * h[i]`, `ght[i] += e * w[i]`.
    #[inline]
    pub fn axpy2(e: f32, h: &[f32], w: &[f32], gw: &mut [f32], ght: &mut [f32]) {
        let k = h.len();
        debug_assert_eq!(w.len(), k);
        debug_assert_eq!(gw.len(), k);
        debug_assert_eq!(ght.len(), k);
        for i in 0..k {
            gw[i] = e.mul_add(h[i], gw[i]);
            ght[i] = e.mul_add(w[i], ght[i]);
        }
    }

    /// [`axpy2`] over `|h|`/`|w|` (generic path; signs are applied once
    /// over the accumulated totals, which distributes exactly).
    #[inline]
    pub fn axpy2_abs(e: f32, h: &[f32], w: &[f32], gw: &mut [f32], ght: &mut [f32]) {
        let k = h.len();
        debug_assert_eq!(w.len(), k);
        debug_assert_eq!(gw.len(), k);
        debug_assert_eq!(ght.len(), k);
        for i in 0..k {
            gw[i] = e.mul_add(h[i].abs(), gw[i]);
            ght[i] = e.mul_add(w[i].abs(), ght[i]);
        }
    }

    /// Four simultaneous rank-1 row updates (the dense mu-tile inner
    /// loop): `erow[i] += a0 h0[i] + a1 h1[i] + a2 h2[i] + a3 h3[i]`,
    /// evaluated as a nested FMA chain from `a3` inwards.
    #[inline]
    pub fn fma4(erow: &mut [f32], a: [f32; 4], h0: &[f32], h1: &[f32], h2: &[f32], h3: &[f32]) {
        let n = erow.len();
        debug_assert!(h0.len() == n && h1.len() == n && h2.len() == n && h3.len() == n);
        for i in 0..n {
            erow[i] = a[0].mul_add(
                h0[i],
                a[1].mul_add(h1[i], a[2].mul_add(h2[i], a[3].mul_add(h3[i], erow[i]))),
            );
        }
    }

    /// Kill gradient entries whose parameter is exactly zero
    /// (`sign(0) = 0` on the non-negative fast path).
    #[inline]
    pub fn zero_kill(g: &mut [f32], x: &[f32]) {
        debug_assert_eq!(g.len(), x.len());
        for (gv, &xv) in g.iter_mut().zip(x.iter()) {
            if xv == 0.0 {
                *gv = 0.0;
            }
        }
    }

    /// `g[i] *= sign0(x[i])` — the deferred sign fixup of the generic
    /// (possibly-negative) path.
    #[inline]
    pub fn scale_by_sign(g: &mut [f32], x: &[f32]) {
        debug_assert_eq!(g.len(), x.len());
        for (gv, &xv) in g.iter_mut().zip(x.iter()) {
            *gv *= super::super::native::sign0(xv);
        }
    }
}

/// AVX2+FMA twins of [`scalar`]. Every function requires the `avx2` and
/// `fma` CPU features (callers dispatch through [`active_tier`]); that
/// shared precondition is the only safety obligation, so it is stated
/// here once rather than per function.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::missing_safety_doc)]
pub mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum matching [`super::scalar::reduce8`]'s tree.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn reduce8(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps(acc, 1);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
        _mm_cvtss_f32(s1)
    }

    /// `|x|` by masking the sign bit (exact).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn absv(x: __m256) -> __m256 {
        _mm256_and_ps(x, _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff)))
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_fmadd_ps(av, bv, acc);
        }
        let mut s = reduce8(acc);
        for i in chunks * 8..n {
            s = a[i].mul_add(b[i], s);
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_abs(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let av = absv(_mm256_loadu_ps(a.as_ptr().add(i)));
            let bv = absv(_mm256_loadu_ps(b.as_ptr().add(i)));
            acc = _mm256_fmadd_ps(av, bv, acc);
        }
        let mut s = reduce8(acc);
        for i in chunks * 8..n {
            s = a[i].abs().mul_add(b[i].abs(), s);
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let chunks = n / 8;
        let av = _mm256_set1_ps(a);
        for c in 0..chunks {
            let i = c * 8;
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(av, xv, yv));
        }
        for i in chunks * 8..n {
            y[i] = a.mul_add(x[i], y[i]);
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(e: f32, h: &[f32], w: &[f32], gw: &mut [f32], ght: &mut [f32]) {
        let k = h.len();
        debug_assert_eq!(w.len(), k);
        debug_assert_eq!(gw.len(), k);
        debug_assert_eq!(ght.len(), k);
        let ev = _mm256_set1_ps(e);
        let chunks = k / 8;
        for c in 0..chunks {
            let i = c * 8;
            let hv = _mm256_loadu_ps(h.as_ptr().add(i));
            let gwv = _mm256_loadu_ps(gw.as_ptr().add(i));
            _mm256_storeu_ps(gw.as_mut_ptr().add(i), _mm256_fmadd_ps(ev, hv, gwv));
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            let ghv = _mm256_loadu_ps(ght.as_ptr().add(i));
            _mm256_storeu_ps(ght.as_mut_ptr().add(i), _mm256_fmadd_ps(ev, wv, ghv));
        }
        for i in chunks * 8..k {
            gw[i] = e.mul_add(h[i], gw[i]);
            ght[i] = e.mul_add(w[i], ght[i]);
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2_abs(e: f32, h: &[f32], w: &[f32], gw: &mut [f32], ght: &mut [f32]) {
        let k = h.len();
        debug_assert_eq!(w.len(), k);
        debug_assert_eq!(gw.len(), k);
        debug_assert_eq!(ght.len(), k);
        let ev = _mm256_set1_ps(e);
        let chunks = k / 8;
        for c in 0..chunks {
            let i = c * 8;
            let hv = absv(_mm256_loadu_ps(h.as_ptr().add(i)));
            let gwv = _mm256_loadu_ps(gw.as_ptr().add(i));
            _mm256_storeu_ps(gw.as_mut_ptr().add(i), _mm256_fmadd_ps(ev, hv, gwv));
            let wv = absv(_mm256_loadu_ps(w.as_ptr().add(i)));
            let ghv = _mm256_loadu_ps(ght.as_ptr().add(i));
            _mm256_storeu_ps(ght.as_mut_ptr().add(i), _mm256_fmadd_ps(ev, wv, ghv));
        }
        for i in chunks * 8..k {
            gw[i] = e.mul_add(h[i].abs(), gw[i]);
            ght[i] = e.mul_add(w[i].abs(), ght[i]);
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma4(
        erow: &mut [f32],
        a: [f32; 4],
        h0: &[f32],
        h1: &[f32],
        h2: &[f32],
        h3: &[f32],
    ) {
        let n = erow.len();
        debug_assert!(h0.len() == n && h1.len() == n && h2.len() == n && h3.len() == n);
        let a0 = _mm256_set1_ps(a[0]);
        let a1 = _mm256_set1_ps(a[1]);
        let a2 = _mm256_set1_ps(a[2]);
        let a3 = _mm256_set1_ps(a[3]);
        let chunks = n / 8;
        for c in 0..chunks {
            let i = c * 8;
            let mut e = _mm256_loadu_ps(erow.as_ptr().add(i));
            e = _mm256_fmadd_ps(a3, _mm256_loadu_ps(h3.as_ptr().add(i)), e);
            e = _mm256_fmadd_ps(a2, _mm256_loadu_ps(h2.as_ptr().add(i)), e);
            e = _mm256_fmadd_ps(a1, _mm256_loadu_ps(h1.as_ptr().add(i)), e);
            e = _mm256_fmadd_ps(a0, _mm256_loadu_ps(h0.as_ptr().add(i)), e);
            _mm256_storeu_ps(erow.as_mut_ptr().add(i), e);
        }
        for i in chunks * 8..n {
            erow[i] = a[0].mul_add(
                h0[i],
                a[1].mul_add(h1[i], a[2].mul_add(h2[i], a[3].mul_add(h3[i], erow[i]))),
            );
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn zero_kill(g: &mut [f32], x: &[f32]) {
        debug_assert_eq!(g.len(), x.len());
        let n = g.len();
        let chunks = n / 8;
        let zero = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            // NEQ_UQ: true for x != 0 and for NaN, matching the scalar
            // `if x == 0.0` test exactly (including -0.0).
            let keep = _mm256_cmp_ps::<{ _CMP_NEQ_UQ }>(xv, zero);
            let gv = _mm256_loadu_ps(g.as_ptr().add(i));
            _mm256_storeu_ps(g.as_mut_ptr().add(i), _mm256_and_ps(gv, keep));
        }
        for i in chunks * 8..n {
            if x[i] == 0.0 {
                g[i] = 0.0;
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale_by_sign(g: &mut [f32], x: &[f32]) {
        debug_assert_eq!(g.len(), x.len());
        let n = g.len();
        let chunks = n / 8;
        let zero = _mm256_setzero_ps();
        let neg0 = _mm256_set1_ps(-0.0);
        let one = _mm256_set1_ps(1.0);
        for c in 0..chunks {
            let i = c * 8;
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            // ±1 by sign bit, zeroed where x == ±0, NaN where x is NaN
            // — exactly sign0's value set.
            let s = _mm256_or_ps(_mm256_and_ps(xv, neg0), one);
            let nz = _mm256_cmp_ps::<{ _CMP_NEQ_UQ }>(xv, zero);
            let s = _mm256_and_ps(s, nz);
            let nan = _mm256_cmp_ps::<{ _CMP_UNORD_Q }>(xv, xv);
            let s = _mm256_blendv_ps(s, xv, nan);
            let gv = _mm256_loadu_ps(g.as_ptr().add(i));
            _mm256_storeu_ps(g.as_mut_ptr().add(i), _mm256_mul_ps(gv, s));
        }
        for i in chunks * 8..n {
            g[i] *= super::super::native::sign0(x[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.37 - 3.0) * 0.71).collect();
        let b: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.13 + 0.2) * -0.53).collect();
        (a, b)
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn tiers_agree_bitwise_on_all_ops() {
        if !avx2_available() {
            eprintln!("skipping: no AVX2+FMA on this host");
            return;
        }
        for n in [0usize, 1, 3, 7, 8, 9, 16, 17, 31, 64, 100] {
            let (a, b) = vecs(n);
            assert_eq!(scalar::dot(&a, &b), unsafe { avx2::dot(&a, &b) }, "dot n={n}");
            assert_eq!(
                scalar::dot_abs(&a, &b),
                unsafe { avx2::dot_abs(&a, &b) },
                "dot_abs n={n}"
            );

            let (mut y1, x) = vecs(n);
            let mut y2 = y1.clone();
            scalar::axpy(&mut y1, 0.77, &x);
            unsafe { avx2::axpy(&mut y2, 0.77, &x) };
            assert_eq!(y1, y2, "axpy n={n}");

            let (h, w) = vecs(n);
            let mut gw1 = vec![0.25f32; n];
            let mut ght1 = vec![-0.5f32; n];
            let (mut gw2, mut ght2) = (gw1.clone(), ght1.clone());
            scalar::axpy2(1.3, &h, &w, &mut gw1, &mut ght1);
            unsafe { avx2::axpy2(1.3, &h, &w, &mut gw2, &mut ght2) };
            assert_eq!(gw1, gw2, "axpy2 gw n={n}");
            assert_eq!(ght1, ght2, "axpy2 ght n={n}");
            scalar::axpy2_abs(-0.9, &h, &w, &mut gw1, &mut ght1);
            unsafe { avx2::axpy2_abs(-0.9, &h, &w, &mut gw2, &mut ght2) };
            assert_eq!(gw1, gw2, "axpy2_abs gw n={n}");
            assert_eq!(ght1, ght2, "axpy2_abs ght n={n}");

            let (mut e1, h0) = vecs(n);
            let mut e2 = e1.clone();
            let h1: Vec<f32> = h0.iter().map(|v| v * 1.7 - 0.3).collect();
            let h2: Vec<f32> = h0.iter().map(|v| v * -0.6 + 0.1).collect();
            let h3: Vec<f32> = h0.iter().map(|v| v * 0.2 + 2.0).collect();
            let coef = [0.3f32, -1.2, 0.8, 0.05];
            scalar::fma4(&mut e1, coef, &h0, &h1, &h2, &h3);
            unsafe { avx2::fma4(&mut e2, coef, &h0, &h1, &h2, &h3) };
            assert_eq!(e1, e2, "fma4 n={n}");

            // sign fixups, with exact zeros and negative zeros mixed in
            let mut xs = a.clone();
            if n > 2 {
                xs[1] = 0.0;
                xs[2] = -0.0;
            }
            let mut g1 = b.clone();
            let mut g2 = b.clone();
            scalar::zero_kill(&mut g1, &xs);
            unsafe { avx2::zero_kill(&mut g2, &xs) };
            assert_eq!(g1, g2, "zero_kill n={n}");
            let mut g1 = b.clone();
            let mut g2 = b.clone();
            scalar::scale_by_sign(&mut g1, &xs);
            unsafe { avx2::scale_by_sign(&mut g2, &xs) };
            assert_eq!(g1, g2, "scale_by_sign n={n}");
        }
    }

    #[test]
    fn dot_matches_plain_sum_approximately() {
        let (a, b) = vecs(37);
        let naive: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
        assert!((scalar::dot(&a, &b) - naive).abs() < 1e-3 * naive.abs().max(1.0));
        let naive_abs: f32 = a.iter().zip(b.iter()).map(|(x, y)| x.abs() * y.abs()).sum();
        assert!((scalar::dot_abs(&a, &b) - naive_abs).abs() < 1e-3 * naive_abs.max(1.0));
    }

    #[test]
    fn override_round_trips() {
        set_tier_override(Some(SimdTier::Scalar));
        assert_eq!(active_tier(), SimdTier::Scalar);
        set_tier_override(None);
        let _ = active_tier(); // whatever detection says; just must not panic
    }
}
