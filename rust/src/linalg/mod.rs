//! Dense linear algebra substrate: row-major `f32` matrices, a cache-
//! tiled GEMM, and the stacked-block containers the coordinator feeds
//! to the batched HLO part update.

pub mod dense;
pub mod stacked;

pub use dense::Mat;
pub use stacked::StackedBlocks;
