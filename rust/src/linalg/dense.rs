//! Row-major `f32` dense matrix with the handful of operations the
//! samplers need. The GEMM uses an `i-k-j` loop order so the inner loop
//! streams both `B`'s row and `C`'s row — auto-vectorises to FMA on
//! every target we care about.

use crate::rng::{Dist, Rng};
use crate::{Error, Result};

/// Row-major dense `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing buffer (must have `rows * cols` elements).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer len {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// I.i.d. Exponential(rate) entries — the model's prior draw.
    pub fn exponential(rows: usize, cols: usize, rate: f64, rng: &mut Rng) -> Self {
        Mat::from_fn(rows, cols, |_, _| rng.exponential(rate) as f32)
    }

    /// I.i.d. Uniform(lo, hi) entries.
    pub fn uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        Mat::from_fn(rows, cols, |_, _| rng.uniform(lo as f64, hi as f64) as f32)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw data (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// `C = |self| @ |other|` — the model's mean map `mu = |W||H|`.
    pub fn matmul_abs(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut c = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let c_row = c.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                let a = a.abs();
                let b_row = other.row(k);
                for (cj, &b) in c_row.iter_mut().zip(b_row.iter()) {
                    *cj += a * b.abs();
                }
            }
        }
        Ok(c)
    }

    /// Plain `C = self @ other`.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut c = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let c_row = c.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                let b_row = other.row(k);
                for (cj, &b) in c_row.iter_mut().zip(b_row.iter()) {
                    *cj += a * b;
                }
            }
        }
        Ok(c)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// In-place `self = |self|` (the mirroring step).
    pub fn abs_inplace(&mut self) {
        for x in &mut self.data {
            *x = x.abs();
        }
    }

    /// `self += alpha * other` (elementwise).
    pub fn axpy(&mut self, alpha: f32, other: &Mat) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape("axpy shape mismatch".into()));
        }
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Add i.i.d. N(0, sd^2) noise to every entry.
    pub fn add_noise(&mut self, sd: f32, rng: &mut Rng) {
        // buffered fill keeps the hot loop branch-free
        let mut buf = vec![0f32; self.data.len()];
        rng.fill_normal_f32(&mut buf, 0.0, sd);
        for (x, n) in self.data.iter_mut().zip(buf.iter()) {
            *x += n;
        }
    }

    /// Sum of |entries| (for the exponential-prior log density).
    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }

    /// Frobenius distance to `other`.
    pub fn frob_dist(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Copy a row-range/col-range sub-block into a new matrix.
    pub fn slice_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        debug_assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for (oi, i) in (r0..r1).enumerate() {
            out.row_mut(oi)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `block` back into the row/col range it was sliced from.
    pub fn write_block(&mut self, r0: usize, c0: usize, block: &Mat) {
        debug_assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for bi in 0..block.rows {
            let dst = &mut self.row_mut(r0 + bi)[c0..c0 + block.cols];
            dst.copy_from_slice(block.row(bi));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_abs_uses_magnitudes() {
        let a = Mat::from_vec(1, 2, vec![-1.0, 2.0]).unwrap();
        let b = Mat::from_vec(2, 1, vec![3.0, -4.0]).unwrap();
        assert_eq!(a.matmul_abs(&b).unwrap().get(0, 0), 11.0);
        assert_eq!(a.matmul(&b).unwrap().get(0, 0), -11.0);
    }

    #[test]
    fn matmul_shape_error() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let a = Mat::uniform(5, 7, -1.0, 1.0, &mut rng);
        let t = a.transpose();
        assert_eq!(t.shape(), (7, 5));
        assert_eq!(t.transpose(), a);
        assert_eq!(a.get(2, 6), t.get(6, 2));
    }

    #[test]
    fn slice_and_write_block_roundtrip() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::uniform(8, 8, 0.0, 1.0, &mut rng);
        let blk = a.slice_block(2, 6, 4, 8);
        assert_eq!(blk.shape(), (4, 4));
        assert_eq!(blk.get(0, 0), a.get(2, 4));
        let mut b = Mat::zeros(8, 8);
        b.write_block(2, 4, &blk);
        assert_eq!(b.get(5, 7), a.get(5, 7));
        assert_eq!(b.get(0, 0), 0.0);
    }

    #[test]
    fn mirroring_abs() {
        let mut a = Mat::from_vec(1, 3, vec![-1.5, 0.0, 2.0]).unwrap();
        a.abs_inplace();
        assert_eq!(a.as_slice(), &[1.5, 0.0, 2.0]);
    }

    #[test]
    fn noise_moments() {
        let mut rng = Rng::seed_from(3);
        let mut a = Mat::zeros(300, 300);
        a.add_noise(0.5, &mut rng);
        let n = (300 * 300) as f64;
        let mean: f64 = a.as_slice().iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 =
            a.as_slice().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01);
        assert!((var - 0.25).abs() < 0.01);
    }

    #[test]
    fn exponential_init_positive() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::exponential(10, 10, 2.0, &mut rng);
        assert!(a.as_slice().iter().all(|&x| x > 0.0));
        let mean: f32 = a.as_slice().iter().sum::<f32>() / 100.0;
        assert!((mean - 0.5).abs() < 0.2, "{mean}");
    }
}
