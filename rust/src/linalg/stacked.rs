//! Stacked block tensors `[B, r, c]` — the layout the batched HLO
//! part-update executable consumes. Keeping factor blocks stacked (and
//! the data blocks pre-stacked per part at setup) makes one iteration a
//! single PJRT dispatch plus two cheap permuted copies.

use crate::linalg::Mat;
use crate::{Error, Result};

/// Contiguous stack of `b` equally-shaped `rows x cols` f32 blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct StackedBlocks {
    b: usize,
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl StackedBlocks {
    pub fn zeros(b: usize, rows: usize, cols: usize) -> Self {
        StackedBlocks { b, rows, cols, data: vec![0.0; b * rows * cols] }
    }

    /// Stack copies of the given blocks (all must share a shape).
    pub fn from_blocks(blocks: &[Mat]) -> Result<Self> {
        let first = blocks
            .first()
            .ok_or_else(|| Error::Shape("empty block list".into()))?;
        let (rows, cols) = first.shape();
        let mut out = StackedBlocks::zeros(blocks.len(), rows, cols);
        for (i, blk) in blocks.iter().enumerate() {
            if blk.shape() != (rows, cols) {
                return Err(Error::Shape(format!(
                    "block {i} shape {:?} != {:?}",
                    blk.shape(),
                    (rows, cols)
                )));
            }
            out.block_mut(i).copy_from_slice(blk.as_slice());
        }
        Ok(out)
    }

    /// Re-assemble a full matrix from row-stripe blocks `[B, m, c]`
    /// stacked in stripe order (the W layout).
    pub fn to_row_stripes(&self) -> Mat {
        let mut m = Mat::zeros(self.b * self.rows, self.cols);
        for bi in 0..self.b {
            for r in 0..self.rows {
                let dst = m.row_mut(bi * self.rows + r);
                dst.copy_from_slice(self.block_row(bi, r));
            }
        }
        m
    }

    /// Re-assemble a full matrix from column-stripe blocks `[B, r, n]`
    /// stacked in stripe order (the H layout: block b holds columns
    /// `b*n .. (b+1)*n`).
    pub fn to_col_stripes(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.b * self.cols);
        for bi in 0..self.b {
            for r in 0..self.rows {
                let src = self.block_row(bi, r);
                m.row_mut(r)[bi * self.cols..(bi + 1) * self.cols]
                    .copy_from_slice(src);
            }
        }
        m
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.b
    }

    #[inline]
    pub fn block_shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn dims(&self) -> [usize; 3] {
        [self.b, self.rows, self.cols]
    }

    #[inline]
    pub fn block(&self, i: usize) -> &[f32] {
        let sz = self.rows * self.cols;
        &self.data[i * sz..(i + 1) * sz]
    }

    #[inline]
    pub fn block_mut(&mut self, i: usize) -> &mut [f32] {
        let sz = self.rows * self.cols;
        &mut self.data[i * sz..(i + 1) * sz]
    }

    #[inline]
    pub fn block_row(&self, i: usize, r: usize) -> &[f32] {
        let base = i * self.rows * self.cols + r * self.cols;
        &self.data[base..base + self.cols]
    }

    /// View block `i` as a [`Mat`] copy.
    pub fn block_mat(&self, i: usize) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.block(i).to_vec()).unwrap()
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Gather into `out`: `out.block[b] = self.block[perm[b]]`.
    /// Used to align H column-stripes with the current part's diagonal.
    pub fn gather_perm_into(&self, perm: &[usize], out: &mut StackedBlocks) {
        debug_assert_eq!(perm.len(), self.b);
        debug_assert_eq!(out.dims(), self.dims());
        let sz = self.rows * self.cols;
        for (b, &src) in perm.iter().enumerate() {
            out.data[b * sz..(b + 1) * sz]
                .copy_from_slice(&self.data[src * sz..(src + 1) * sz]);
        }
    }

    /// Scatter from `other`: `self.block[perm[b]] = other.block[b]`
    /// (inverse of [`Self::gather_perm_into`]).
    pub fn scatter_perm_from(&mut self, perm: &[usize], other: &StackedBlocks) {
        debug_assert_eq!(perm.len(), self.b);
        let sz = self.rows * self.cols;
        for (b, &dst) in perm.iter().enumerate() {
            self.data[dst * sz..(dst + 1) * sz]
                .copy_from_slice(&other.data[b * sz..(b + 1) * sz]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn stack_and_unstack_row_stripes() {
        let mut rng = Rng::seed_from(1);
        let full = Mat::uniform(8, 4, 0.0, 1.0, &mut rng);
        let blocks: Vec<Mat> =
            (0..4).map(|b| full.slice_block(b * 2, (b + 1) * 2, 0, 4)).collect();
        let stacked = StackedBlocks::from_blocks(&blocks).unwrap();
        assert_eq!(stacked.dims(), [4, 2, 4]);
        assert_eq!(stacked.to_row_stripes(), full);
    }

    #[test]
    fn stack_and_unstack_col_stripes() {
        let mut rng = Rng::seed_from(2);
        let full = Mat::uniform(3, 8, 0.0, 1.0, &mut rng);
        let blocks: Vec<Mat> =
            (0..4).map(|b| full.slice_block(0, 3, b * 2, (b + 1) * 2)).collect();
        let stacked = StackedBlocks::from_blocks(&blocks).unwrap();
        assert_eq!(stacked.to_col_stripes(), full);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Rng::seed_from(3);
        let blocks: Vec<Mat> =
            (0..4).map(|_| Mat::uniform(2, 3, 0.0, 1.0, &mut rng)).collect();
        let orig = StackedBlocks::from_blocks(&blocks).unwrap();
        let perm = [2usize, 0, 3, 1];
        let mut gathered = StackedBlocks::zeros(4, 2, 3);
        orig.gather_perm_into(&perm, &mut gathered);
        for b in 0..4 {
            assert_eq!(gathered.block(b), orig.block(perm[b]));
        }
        let mut restored = StackedBlocks::zeros(4, 2, 3);
        restored.scatter_perm_from(&perm, &gathered);
        assert_eq!(restored, orig);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let blocks = vec![Mat::zeros(2, 2), Mat::zeros(2, 3)];
        assert!(StackedBlocks::from_blocks(&blocks).is_err());
    }
}
