//! CSR sparse matrix + its B×B block decomposition.
//!
//! For sparse MF (MovieLens) the likelihood runs over *observed* entries
//! only; `N` in the paper's `N/|Π|` factor becomes the total nnz and
//! `|Π|` the nnz inside the part. The block decomposition stores each
//! grid cell in **block-local CSR** (row `indptr` + column/value
//! arrays): a block update walks rows, so each observed row's `gw`
//! accumulator is loaded once, updated across all the row's entries,
//! and stored once — instead of being gathered/scattered per entry as
//! the earlier local-index COO layout did.

use crate::partition::{GridPartition, Part};
use crate::{Error, Result};

/// Compressed sparse row f32 matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    vals: Vec<f32>,
}

impl Csr {
    /// Build from (row, col, value) triplets (need not be sorted;
    /// duplicates are rejected).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &mut Vec<(u32, u32, f32)>,
    ) -> Result<Self> {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        for w in triplets.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                return Err(Error::Config(format!(
                    "duplicate entry at ({}, {})",
                    w[0].0, w[0].1
                )));
            }
        }
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut vals = Vec::with_capacity(triplets.len());
        for &(r, c, v) in triplets.iter() {
            if r as usize >= rows || c as usize >= cols {
                return Err(Error::Shape(format!(
                    "entry ({r},{c}) outside {rows}x{cols}"
                )));
            }
            indptr[r as usize + 1] += 1;
            indices.push(c);
            vals.push(v);
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Ok(Csr { rows, cols, indptr, indices, vals })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// (column, value) pairs of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let r = self.indptr[i]..self.indptr[i + 1];
        self.indices[r.clone()]
            .iter()
            .copied()
            .zip(self.vals[r].iter().copied())
    }

    /// Mean of the stored values.
    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().map(|&v| v as f64).sum::<f64>() / self.vals.len() as f64
    }
}

/// One grid cell of a [`BlockedSparse`] in block-local CSR: `indptr`
/// has `nrows + 1` entries (local row `i` owns `cols`/`vals` indices
/// `indptr[i]..indptr[i+1]`), columns within a row sorted ascending.
#[derive(Clone, Debug)]
pub struct BlockEntries {
    nrows: usize,
    indptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f32>,
}

impl Default for BlockEntries {
    fn default() -> Self {
        BlockEntries { nrows: 0, indptr: vec![0], cols: Vec::new(), vals: Vec::new() }
    }
}

impl BlockEntries {
    /// Append one entry. Entries must arrive sorted by (row, col) —
    /// which the [`BlockedSparse::from_csr`] walk guarantees.
    fn push(&mut self, li: u32, lj: u32, v: f32) {
        debug_assert!(self.indptr.len() <= li as usize + 1, "entries must arrive row-sorted");
        while self.indptr.len() <= li as usize {
            self.indptr.push(self.cols.len() as u32);
        }
        self.cols.push(lj);
        self.vals.push(v);
    }

    /// Pad `indptr` out to `nrows + 1` entries (closing trailing empty
    /// rows) and fix the block's row count.
    fn finish(&mut self, nrows: usize) {
        while self.indptr.len() <= nrows {
            self.indptr.push(self.cols.len() as u32);
        }
        self.nrows = nrows;
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Local row count of the block (the row stripe's length).
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Row pointer array (`nrows + 1` entries).
    #[inline]
    pub fn indptr(&self) -> &[u32] {
        &self.indptr
    }

    /// Local column index per stored entry, row-major.
    #[inline]
    pub fn cols(&self) -> &[u32] {
        &self.cols
    }

    /// Stored values, row-major.
    #[inline]
    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    /// Expand back to (local row, local col, value) triples in storage
    /// order — the old COO view, for tests and reference kernels.
    pub fn iter_coo(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.nrows).flat_map(move |i| {
            let r = self.indptr[i] as usize..self.indptr[i + 1] as usize;
            self.cols[r.clone()]
                .iter()
                .copied()
                .zip(self.vals[r].iter().copied())
                .map(move |(j, v)| (i as u32, j, v))
        })
    }
}

/// B×B block decomposition of a sparse matrix over a [`GridPartition`].
#[derive(Clone, Debug)]
pub struct BlockedSparse {
    grid: GridPartition,
    /// Block (bi, bj) at index `bi * B + bj`.
    blocks: Vec<BlockEntries>,
    nnz: usize,
}

impl BlockedSparse {
    pub fn from_csr(csr: &Csr, b: usize) -> Result<Self> {
        let grid = GridPartition::new(csr.rows(), csr.cols(), b)?;
        let mut blocks: Vec<BlockEntries> = vec![BlockEntries::default(); b * b];
        // The global-CSR walk visits rows ascending and columns within a
        // row ascending, so each block receives its entries in exactly
        // the (row, col) order its local CSR builder requires.
        for i in 0..csr.rows() {
            let bi = grid.row_stripe_of(i);
            let li = (i - grid.row_range(bi).start) as u32;
            for (j, v) in csr.row(i) {
                let bj = grid.col_stripe_of(j as usize);
                let lj = (j as usize - grid.col_range(bj).start) as u32;
                blocks[bi * b + bj].push(li, lj, v);
            }
        }
        for bi in 0..b {
            let nrows = grid.row_range(bi).len();
            for bj in 0..b {
                blocks[bi * b + bj].finish(nrows);
            }
        }
        Ok(BlockedSparse { grid, blocks, nnz: csr.nnz() })
    }

    #[inline]
    pub fn grid(&self) -> &GridPartition {
        &self.grid
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.grid.b()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    #[inline]
    pub fn block(&self, bi: usize, bj: usize) -> &BlockEntries {
        &self.blocks[bi * self.grid.b() + bj]
    }

    /// nnz inside a part (`|Π|` for sparse data).
    pub fn part_nnz(&self, part: &Part) -> usize {
        (0..self.grid.b())
            .map(|b| self.block(b, part.perm[b]).nnz())
            .sum()
    }

    /// `N/|Π|` with N = total nnz.
    pub fn scale(&self, part: &Part) -> f32 {
        let pn = self.part_nnz(part);
        if pn == 0 {
            0.0
        } else {
            self.nnz as f32 / pn as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        let mut t = vec![
            (0u32, 1u32, 1.0f32),
            (0, 3, 2.0),
            (1, 0, 3.0),
            (2, 2, 4.0),
            (3, 3, 5.0),
            (3, 0, 6.0),
        ];
        Csr::from_triplets(4, 4, &mut t).unwrap()
    }

    #[test]
    fn csr_roundtrip() {
        let m = small();
        assert_eq!(m.nnz(), 6);
        let row0: Vec<_> = m.row(0).collect();
        assert_eq!(row0, vec![(1, 1.0), (3, 2.0)]);
        let row3: Vec<_> = m.row(3).collect();
        assert_eq!(row3, vec![(0, 6.0), (3, 5.0)]); // sorted by col
        assert!((m.mean() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn duplicate_rejected() {
        let mut t = vec![(0u32, 0u32, 1.0f32), (0, 0, 2.0)];
        assert!(Csr::from_triplets(2, 2, &mut t).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mut t = vec![(5u32, 0u32, 1.0f32)];
        assert!(Csr::from_triplets(2, 2, &mut t).is_err());
    }

    #[test]
    fn blocked_preserves_all_entries() {
        let m = small();
        let bs = BlockedSparse::from_csr(&m, 2).unwrap();
        let total: usize = (0..2)
            .flat_map(|bi| (0..2).map(move |bj| (bi, bj)))
            .map(|(bi, bj)| bs.block(bi, bj).nnz())
            .sum();
        assert_eq!(total, m.nnz());
        // entry (3,3)=5.0 lands in block (1,1) at local (1,1)
        let blk = bs.block(1, 1);
        assert!(blk.iter_coo().any(|(r, c, v)| (r, c, v) == (1, 1, 5.0)));
    }

    #[test]
    fn block_csr_indptr_is_consistent() {
        let m = small();
        for b in [1usize, 2, 4] {
            let bs = BlockedSparse::from_csr(&m, b).unwrap();
            for bi in 0..b {
                let nrows = bs.grid().row_range(bi).len();
                for bj in 0..b {
                    let blk = bs.block(bi, bj);
                    assert_eq!(blk.nrows(), nrows);
                    assert_eq!(blk.indptr().len(), nrows + 1);
                    assert_eq!(blk.indptr()[0], 0);
                    assert_eq!(blk.indptr()[nrows] as usize, blk.nnz());
                    assert!(blk.indptr().windows(2).all(|w| w[0] <= w[1]));
                    // every column index stays inside the column stripe,
                    // and the COO expansion matches nnz
                    let ncols = bs.grid().col_range(bj).len();
                    assert!(blk.cols().iter().all(|&c| (c as usize) < ncols));
                    assert_eq!(blk.iter_coo().count(), blk.nnz());
                }
            }
        }
    }

    #[test]
    fn part_nnz_and_scale() {
        let m = small();
        let bs = BlockedSparse::from_csr(&m, 2).unwrap();
        let diag = Part::cyclic(2, 0);
        let off = Part::cyclic(2, 1);
        assert_eq!(bs.part_nnz(&diag) + bs.part_nnz(&off), m.nnz());
        if bs.part_nnz(&diag) > 0 {
            assert!((bs.scale(&diag) - 6.0 / bs.part_nnz(&diag) as f32).abs() < 1e-6);
        }
    }
}
