//! CSR sparse matrix + its B×B block decomposition.
//!
//! For sparse MF (MovieLens) the likelihood runs over *observed* entries
//! only; `N` in the paper's `N/|Π|` factor becomes the total nnz and
//! `|Π|` the nnz inside the part. The block decomposition stores each
//! grid cell as a local-index COO triple list, so a block update is one
//! contiguous walk.

use crate::partition::{GridPartition, Part};
use crate::{Error, Result};

/// Compressed sparse row f32 matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    vals: Vec<f32>,
}

impl Csr {
    /// Build from (row, col, value) triplets (need not be sorted;
    /// duplicates are rejected).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &mut Vec<(u32, u32, f32)>,
    ) -> Result<Self> {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        for w in triplets.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                return Err(Error::Config(format!(
                    "duplicate entry at ({}, {})",
                    w[0].0, w[0].1
                )));
            }
        }
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut vals = Vec::with_capacity(triplets.len());
        for &(r, c, v) in triplets.iter() {
            if r as usize >= rows || c as usize >= cols {
                return Err(Error::Shape(format!(
                    "entry ({r},{c}) outside {rows}x{cols}"
                )));
            }
            indptr[r as usize + 1] += 1;
            indices.push(c);
            vals.push(v);
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Ok(Csr { rows, cols, indptr, indices, vals })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// (column, value) pairs of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let r = self.indptr[i]..self.indptr[i + 1];
        self.indices[r.clone()]
            .iter()
            .copied()
            .zip(self.vals[r].iter().copied())
    }

    /// Mean of the stored values.
    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().map(|&v| v as f64).sum::<f64>() / self.vals.len() as f64
    }
}

/// One grid cell of a [`BlockedSparse`]: local-index COO, sorted by
/// (row, col) for a cache-friendly sequential walk.
#[derive(Clone, Debug, Default)]
pub struct BlockEntries {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl BlockEntries {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

/// B×B block decomposition of a sparse matrix over a [`GridPartition`].
#[derive(Clone, Debug)]
pub struct BlockedSparse {
    grid: GridPartition,
    /// Block (bi, bj) at index `bi * B + bj`.
    blocks: Vec<BlockEntries>,
    nnz: usize,
}

impl BlockedSparse {
    pub fn from_csr(csr: &Csr, b: usize) -> Result<Self> {
        let grid = GridPartition::new(csr.rows(), csr.cols(), b)?;
        let mut blocks: Vec<BlockEntries> = vec![BlockEntries::default(); b * b];
        for i in 0..csr.rows() {
            let bi = grid.row_stripe_of(i);
            let li = (i - grid.row_range(bi).start) as u32;
            for (j, v) in csr.row(i) {
                let bj = grid.col_stripe_of(j as usize);
                let lj = (j as usize - grid.col_range(bj).start) as u32;
                let blk = &mut blocks[bi * b + bj];
                blk.rows.push(li);
                blk.cols.push(lj);
                blk.vals.push(v);
            }
        }
        Ok(BlockedSparse { grid, blocks, nnz: csr.nnz() })
    }

    #[inline]
    pub fn grid(&self) -> &GridPartition {
        &self.grid
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.grid.b()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    #[inline]
    pub fn block(&self, bi: usize, bj: usize) -> &BlockEntries {
        &self.blocks[bi * self.grid.b() + bj]
    }

    /// nnz inside a part (`|Π|` for sparse data).
    pub fn part_nnz(&self, part: &Part) -> usize {
        (0..self.grid.b())
            .map(|b| self.block(b, part.perm[b]).nnz())
            .sum()
    }

    /// `N/|Π|` with N = total nnz.
    pub fn scale(&self, part: &Part) -> f32 {
        let pn = self.part_nnz(part);
        if pn == 0 {
            0.0
        } else {
            self.nnz as f32 / pn as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        let mut t = vec![
            (0u32, 1u32, 1.0f32),
            (0, 3, 2.0),
            (1, 0, 3.0),
            (2, 2, 4.0),
            (3, 3, 5.0),
            (3, 0, 6.0),
        ];
        Csr::from_triplets(4, 4, &mut t).unwrap()
    }

    #[test]
    fn csr_roundtrip() {
        let m = small();
        assert_eq!(m.nnz(), 6);
        let row0: Vec<_> = m.row(0).collect();
        assert_eq!(row0, vec![(1, 1.0), (3, 2.0)]);
        let row3: Vec<_> = m.row(3).collect();
        assert_eq!(row3, vec![(0, 6.0), (3, 5.0)]); // sorted by col
        assert!((m.mean() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn duplicate_rejected() {
        let mut t = vec![(0u32, 0u32, 1.0f32), (0, 0, 2.0)];
        assert!(Csr::from_triplets(2, 2, &mut t).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mut t = vec![(5u32, 0u32, 1.0f32)];
        assert!(Csr::from_triplets(2, 2, &mut t).is_err());
    }

    #[test]
    fn blocked_preserves_all_entries() {
        let m = small();
        let bs = BlockedSparse::from_csr(&m, 2).unwrap();
        let total: usize = (0..2)
            .flat_map(|bi| (0..2).map(move |bj| (bi, bj)))
            .map(|(bi, bj)| bs.block(bi, bj).nnz())
            .sum();
        assert_eq!(total, m.nnz());
        // entry (3,3)=5.0 lands in block (1,1) at local (1,1)
        let blk = bs.block(1, 1);
        let pos = blk
            .vals
            .iter()
            .position(|&v| v == 5.0)
            .expect("value present");
        assert_eq!((blk.rows[pos], blk.cols[pos]), (1, 1));
    }

    #[test]
    fn part_nnz_and_scale() {
        let m = small();
        let bs = BlockedSparse::from_csr(&m, 2).unwrap();
        let diag = Part::cyclic(2, 0);
        let off = Part::cyclic(2, 1);
        assert_eq!(bs.part_nnz(&diag) + bs.part_nnz(&off), m.nnz());
        if bs.part_nnz(&diag) > 0 {
            assert!((bs.scale(&diag) - 6.0 / bs.part_nnz(&diag) as f32).abs() < 1e-6);
        }
    }
}
