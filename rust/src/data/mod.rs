//! Datasets: synthetic generators matching the paper's experiments and
//! the sparse-matrix substrate for the MovieLens-scale runs.

pub mod audio;
pub mod movielens;
pub mod sparse;
pub mod synth;

pub use sparse::{BlockedSparse, Csr};

use crate::linalg::Mat;

/// A dense observed matrix plus (when synthetic) its generative factors.
#[derive(Clone, Debug)]
pub struct DenseDataset {
    /// Observed matrix V (I × J).
    pub v: Mat,
    /// Ground-truth dictionary, when known.
    pub w_true: Option<Mat>,
    /// Ground-truth weights, when known.
    pub h_true: Option<Mat>,
}

impl DenseDataset {
    pub fn shape(&self) -> (usize, usize) {
        self.v.shape()
    }

    /// Number of observed entries (N in the paper).
    pub fn n(&self) -> usize {
        self.v.rows() * self.v.cols()
    }
}
