//! Synthetic piano spectrogram (Fig. 3 substitute — see DESIGN.md §3).
//!
//! The paper decomposes the magnitude spectrum of a 5-second piano
//! excerpt (256 frequency bins × 256 frames, K = 8). We synthesise an
//! equivalent: per-note harmonic spectral templates (decaying partials
//! as narrow Gaussian bumps) and piano-roll activations with exponential
//! decay for a short chord progression, then draw V ~ Po(scale · W H).
//! The ground-truth templates let tests verify that the sampler recovers
//! note spectra, which is exactly what the paper's Fig. 3 shows
//! qualitatively.

use crate::data::DenseDataset;
use crate::linalg::Mat;
use crate::rng::{Dist, Rng};

/// Notes of a C-major-ish progression (fundamental bin positions chosen
/// so the first ~8 partials of every note stay inside 256 bins).
const NOTE_F0_BINS: [f64; 8] = [8.0, 9.0, 10.1, 12.0, 13.5, 16.0, 18.0, 20.2];

/// Number of partials per note template.
const PARTIALS: usize = 8;

/// Build one harmonic template column (length `bins`).
fn note_template(bins: usize, f0: f64) -> Vec<f32> {
    let mut t = vec![0f32; bins];
    for p in 1..=PARTIALS {
        let centre = f0 * p as f64;
        if centre >= bins as f64 - 2.0 {
            break;
        }
        let amp = 1.0 / p as f64; // spectral roll-off
        let sigma = 1.2;
        let lo = (centre - 4.0 * sigma).max(0.0) as usize;
        let hi = ((centre + 4.0 * sigma) as usize).min(bins - 1);
        for (bin, tv) in t.iter_mut().enumerate().take(hi + 1).skip(lo) {
            let d = (bin as f64 - centre) / sigma;
            *tv += (amp * (-0.5 * d * d).exp()) as f32;
        }
    }
    t
}

/// Piano-roll activations: each note fires in a few segments of the
/// progression and decays exponentially within a segment (hammer strike
/// then ring-out), mimicking real piano envelopes.
fn note_activation(frames: usize, note: usize, n_notes: usize, rng: &mut Rng) -> Vec<f32> {
    let mut a = vec![0f32; frames];
    let seg = frames / 8; // 8 beats
    for beat in 0..8 {
        // simple chord chart: note fires if it belongs to the beat's chord
        let fires = match beat % 4 {
            0 => note % 2 == 0,             // tonic-ish: even notes
            1 => note % 3 == 0,
            2 => note >= n_notes / 2,       // upper voices
            _ => note % 2 == 1,
        };
        if !fires {
            continue;
        }
        let onset = beat * seg + rng.next_below(3) as usize;
        let velocity = 0.7 + 0.6 * rng.next_f32();
        let decay = 0.04 + 0.02 * rng.next_f32();
        for f in onset..frames.min(onset + 2 * seg) {
            let dt = (f - onset) as f32;
            a[f] += velocity * (-decay * dt).exp();
        }
    }
    a
}

/// Synthesise the Fig. 3 workload: a `bins × frames` Poisson spectrogram
/// with `NOTE_F0_BINS.len()` ground-truth note components.
pub fn piano_spectrogram(bins: usize, frames: usize, seed: u64) -> DenseDataset {
    let n_notes = NOTE_F0_BINS.len();
    let mut rng = Rng::derive(seed, &[0xa0d10, bins as u64, frames as u64]);
    let w_true = Mat::from_fn(bins, n_notes, |i, k| note_template(bins, NOTE_F0_BINS[k])[i]);
    let mut h_true = Mat::zeros(n_notes, frames);
    for k in 0..n_notes {
        let act = note_activation(frames, k, n_notes, &mut rng);
        h_true.row_mut(k).copy_from_slice(&act);
    }
    // scale so counts are informative (peak mu around ~40)
    let mu = w_true.matmul_abs(&h_true).expect("shape");
    let peak = mu.as_slice().iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    let gain = 40.0 / peak;
    let v = Mat::from_fn(bins, frames, |i, j| {
        rng.poisson((mu.get(i, j) * gain) as f64) as f32
    });
    let mut w_scaled = w_true;
    for x in w_scaled.as_mut_slice() {
        *x *= gain;
    }
    DenseDataset { v, w_true: Some(w_scaled), h_true: Some(h_true) }
}

/// Cosine similarity between two vectors — used to match learned
/// dictionary columns against ground-truth templates.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
    let na: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&y| (y as f64) * (y as f64)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Greedy best-match mean cosine similarity between the columns of a
/// learned dictionary and the true templates (Fig. 3's qualitative
/// "templates recovered" claim, made quantitative).
pub fn dictionary_recovery_score(w_learned: &Mat, w_true: &Mat) -> f64 {
    let k = w_true.cols();
    let wl = w_learned.transpose(); // rows = components
    let wt = w_true.transpose();
    let mut used = vec![false; w_learned.cols()];
    let mut total = 0.0;
    for t in 0..k {
        let mut best = (0.0f64, usize::MAX);
        for l in 0..wl.rows() {
            if used[l] {
                continue;
            }
            let c = cosine(wt.row(t), wl.row(l));
            if c > best.0 {
                best = (c, l);
            }
        }
        if best.1 != usize::MAX {
            used[best.1] = true;
            total += best.0;
        }
    }
    total / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrogram_shapes_and_positivity() {
        let d = piano_spectrogram(256, 256, 1);
        assert_eq!(d.shape(), (256, 256));
        assert!(d.v.as_slice().iter().all(|&v| v >= 0.0));
        let w = d.w_true.as_ref().unwrap();
        assert_eq!(w.shape(), (256, 8));
        // every template has energy
        for k in 0..8 {
            let col: f32 = (0..256).map(|i| w.get(i, k)).sum();
            assert!(col > 0.0, "template {k} empty");
        }
    }

    #[test]
    fn templates_are_harmonic() {
        let t = note_template(256, 10.0);
        // peaks at 10, 20, 30... with decaying amplitude
        assert!(t[10] > t[15]);
        assert!(t[10] > t[20]);
        assert!(t[20] > t[30]);
        assert!(t[20] > 0.3);
    }

    #[test]
    fn activations_cover_time() {
        let mut rng = Rng::seed_from(2);
        let total: f32 = (0..8)
            .map(|k| note_activation(256, k, 8, &mut rng).iter().sum::<f32>())
            .sum();
        assert!(total > 10.0);
    }

    #[test]
    fn recovery_score_perfect_for_truth() {
        let d = piano_spectrogram(128, 64, 3);
        let w = d.w_true.as_ref().unwrap();
        let score = dictionary_recovery_score(w, w);
        assert!(score > 0.999, "{score}");
    }

    #[test]
    fn recovery_score_low_for_noise() {
        let d = piano_spectrogram(128, 64, 4);
        let w = d.w_true.as_ref().unwrap();
        let mut rng = Rng::seed_from(5);
        let noise = Mat::uniform(128, 8, 0.0, 1.0, &mut rng);
        assert!(dictionary_recovery_score(&noise, w) < dictionary_recovery_score(w, w));
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }
}
