//! Synthetic data from the generative model (paper §4.2.1): draw
//! `(W, H)` from the exponential priors and `V` from the Tweedie
//! observation model at `mu = WH`.

use crate::data::DenseDataset;
use crate::linalg::Mat;
use crate::model::tweedie::tweedie_power;
use crate::model::NmfModel;
use crate::rng::{Dist, Rng};

/// Draw one Tweedie observation with mean `mu` for the given `(beta,
/// phi)`. Supported: β=2 (Gaussian), β=1 (Poisson, φ=1), β=0 (gamma),
/// β∈(0,1) (compound Poisson-gamma). Panics on the unsupported interval
/// β∈(1,2) where no Tweedie distribution exists.
pub fn tweedie_sample(mu: f64, phi: f64, beta: f32, rng: &mut Rng) -> f64 {
    let mu = mu.max(1e-9);
    if beta == 2.0 {
        rng.normal_ms(mu, phi.sqrt())
    } else if beta == 1.0 {
        // dispersed Poisson: φ·Po(μ/φ) has mean μ, variance φμ
        if (phi - 1.0).abs() < 1e-12 {
            rng.poisson(mu) as f64
        } else {
            phi * rng.poisson(mu / phi) as f64
        }
    } else if beta == 0.0 {
        // gamma with mean μ, variance φμ²: shape 1/φ, scale φμ
        rng.gamma(1.0 / phi, phi * mu)
    } else if beta > 0.0 && beta < 1.0 {
        rng.tweedie_cp(mu, phi, tweedie_power(beta) as f64)
    } else {
        panic!("no Tweedie distribution for beta = {beta}");
    }
}

/// Generate a dense dataset from the model's generative process.
pub fn from_model(i: usize, j: usize, model: &NmfModel, seed: u64) -> DenseDataset {
    let mut rng = Rng::derive(seed, &[0x5e_ed, i as u64, j as u64]);
    let (w, h) = model.sample_prior(i, j, &mut rng);
    let mu = w.matmul_abs(&h).expect("shape");
    let v = Mat::from_fn(i, j, |r, c| {
        tweedie_sample(mu.get(r, c) as f64, model.phi as f64, model.beta, &mut rng) as f32
    });
    DenseDataset { v, w_true: Some(w), h_true: Some(h) }
}

/// Poisson-NMF synthetic data (Fig. 2a): K columns, exponential priors.
pub fn poisson_nmf(i: usize, j: usize, model: &NmfModel, seed: u64) -> DenseDataset {
    assert_eq!(model.beta, 1.0, "poisson_nmf requires beta = 1");
    from_model(i, j, model, seed)
}

/// Compound-Poisson synthetic data (Fig. 2b, β = 0.5).
pub fn compound_poisson_nmf(i: usize, j: usize, model: &NmfModel, seed: u64) -> DenseDataset {
    assert!(model.beta > 0.0 && model.beta < 1.0);
    from_model(i, j, model, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweedie_sample_means() {
        let mut rng = Rng::seed_from(1);
        for &beta in &[0.0f32, 0.5, 1.0, 2.0] {
            let n = 50_000;
            let mu = 3.0;
            let m: f64 = (0..n)
                .map(|_| tweedie_sample(mu, 1.0, beta, &mut rng))
                .sum::<f64>()
                / n as f64;
            assert!((m - mu).abs() < 0.05 * mu, "beta={beta} mean {m}");
        }
    }

    #[test]
    fn dispersed_poisson_variance() {
        let mut rng = Rng::seed_from(2);
        let (mu, phi) = (4.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| tweedie_sample(mu, phi, 1.0, &mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - mu).abs() < 0.05 * mu);
        assert!((var - phi * mu).abs() < 0.1 * phi * mu, "var {var}");
    }

    #[test]
    #[should_panic(expected = "no Tweedie distribution")]
    fn forbidden_interval_panics() {
        let mut rng = Rng::seed_from(3);
        tweedie_sample(1.0, 1.0, 1.5, &mut rng);
    }

    #[test]
    fn poisson_nmf_dataset_sane() {
        let model = NmfModel::poisson(8);
        let d = poisson_nmf(32, 48, &model, 7);
        assert_eq!(d.shape(), (32, 48));
        assert_eq!(d.n(), 32 * 48);
        // Poisson data: non-negative integers
        assert!(d.v.as_slice().iter().all(|&v| v >= 0.0 && v.fract() == 0.0));
        // mean of V ≈ mean of mu = K * E[w] * E[h] = 8 * 1 * 1
        let mean: f64 = d.v.as_slice().iter().map(|&v| v as f64).sum::<f64>() / d.n() as f64;
        assert!((mean - 8.0).abs() < 1.0, "{mean}");
        let w = d.w_true.unwrap();
        assert_eq!(w.shape(), (32, 8));
    }

    #[test]
    fn compound_poisson_dataset_has_zeros() {
        let model = NmfModel::compound_poisson(2).with_priors(2.0, 2.0);
        let d = compound_poisson_nmf(64, 64, &model, 8);
        let zeros = d.v.as_slice().iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0, "compound Poisson should produce exact zeros");
    }

    #[test]
    fn deterministic_given_seed() {
        let model = NmfModel::poisson(4);
        let a = poisson_nmf(16, 16, &model, 9);
        let b = poisson_nmf(16, 16, &model, 9);
        assert_eq!(a.v, b.v);
        let c = poisson_nmf(16, 16, &model, 10);
        assert_ne!(a.v, c.v);
    }
}
