//! MovieLens-like sparse ratings (Fig. 5/6 substitute — DESIGN.md §3).
//!
//! grouplens.org is not reachable from this environment, so we generate
//! a synthetic dataset with the same statistics MovieLens 10M has:
//! I = 10681 movies × J = 71567 users, ~10M ratings (1.3% density),
//! long-tailed (Zipf) movie/user popularity, and ½-star ratings in
//! [0.5, 5] drawn from a low-rank latent model. `load_movielens` parses
//! the real `ratings.dat` when a copy is available, so the harness runs
//! on the genuine data unchanged if provided.

use std::io::BufRead;

use crate::data::sparse::Csr;
use crate::rng::{Dist, Rng};
use crate::Result;

/// MovieLens 10M dimensions (movies × users).
pub const ML10M_MOVIES: usize = 10_681;
pub const ML10M_USERS: usize = 71_567;
pub const ML10M_RATINGS: usize = 10_000_054;

/// Zipf-ish popularity weights: `w_r = 1 / (r + shift)^alpha`, shuffled
/// so popularity is not index-correlated.
fn popularity(n: usize, alpha: f64, shift: f64, rng: &mut Rng) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n).map(|r| 1.0 / (r as f64 + shift).powf(alpha)).collect();
    // Fisher-Yates shuffle
    for i in (1..n).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        w.swap(i, j);
    }
    let s: f64 = w.iter().sum();
    for x in &mut w {
        *x /= s;
    }
    w
}

/// Alias-method table for O(1) categorical sampling.
struct Alias {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl Alias {
    fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        let sum: f64 = weights.iter().sum();
        let mut prob = vec![0.0; n];
        let mut alias = vec![0u32; n];
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * n as f64 / sum).collect();
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().expect("non-empty");
            let l = *large.last().expect("non-empty");
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] += scaled[s as usize] - 1.0;
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for i in large.into_iter().chain(small) {
            prob[i as usize] = 1.0;
        }
        Alias { prob, alias }
    }

    #[inline]
    fn sample(&self, rng: &mut Rng) -> u32 {
        let i = rng.next_below(self.prob.len() as u64) as usize;
        if rng.next_f64() < self.prob[i] {
            i as u32
        } else {
            self.alias[i]
        }
    }
}

/// Generate a MovieLens-like sparse ratings matrix (movies × users).
///
/// `scale` shrinks every dimension and the rating count proportionally
/// (scale = 1.0 reproduces the full 10M layout; scale = 0.05 is a
/// laptop-friendly half-million-rating variant).
pub fn movielens_like(scale: f64, k: usize, seed: u64) -> Csr {
    let rows = ((ML10M_MOVIES as f64 * scale) as usize).max(8);
    let cols = ((ML10M_USERS as f64 * scale) as usize).max(8);
    let target = ((ML10M_RATINGS as f64 * scale * scale) as usize)
        .min(rows * cols / 4)
        .max(rows + cols);
    movielens_like_dims(rows, cols, target, k, seed)
}

/// Fully parameterised generator (used by the weak-scaling experiments).
pub fn movielens_like_dims(
    rows: usize,
    cols: usize,
    target_nnz: usize,
    k: usize,
    seed: u64,
) -> Csr {
    let mut rng = Rng::derive(seed, &[0x310c, rows as u64, cols as u64]);
    // latent factors: gamma so mu > 0 and mildly skewed
    let wf: Vec<f32> = (0..rows * k).map(|_| rng.gamma(2.0, 0.3) as f32).collect();
    let hf: Vec<f32> = (0..cols * k).map(|_| rng.gamma(2.0, 0.3) as f32).collect();
    let row_pop = popularity(rows, 0.8, 10.0, &mut rng);
    let col_pop = popularity(cols, 0.7, 20.0, &mut rng);
    let row_alias = Alias::new(&row_pop);
    let col_alias = Alias::new(&col_pop);

    // Sample positions with dedup via a hash set of packed (row, col).
    let mut seen = std::collections::HashSet::with_capacity(target_nnz * 2);
    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(target_nnz);
    let mut attempts = 0usize;
    let max_attempts = target_nnz * 20;
    while triplets.len() < target_nnz && attempts < max_attempts {
        attempts += 1;
        let r = row_alias.sample(&mut rng);
        let c = col_alias.sample(&mut rng);
        let key = (r as u64) << 32 | c as u64;
        if !seen.insert(key) {
            continue;
        }
        let mut mu = 0f32;
        for kk in 0..k {
            mu += wf[r as usize * k + kk] * hf[c as usize * k + kk];
        }
        // map mu (mean ~ k*0.36) to the 0.5..5 rating scale with noise
        let base = 3.5 * mu / (k as f32 * 0.36);
        let noisy = base as f64 + 0.4 * rng.normal();
        let rating = (2.0 * noisy).round().clamp(1.0, 10.0) / 2.0;
        triplets.push((r, c, rating as f32));
    }
    Csr::from_triplets(rows, cols, &mut triplets).expect("deduped triplets")
}

/// Parse a real MovieLens `ratings.dat` (`user::movie::rating::ts`).
/// Returns a movies × users CSR with ids remapped densely.
pub fn load_movielens(path: &std::path::Path) -> Result<Csr> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut raw: Vec<(u32, u32, f32)> = Vec::new();
    for line in reader.lines() {
        let line = line?;
        let mut it = line.split("::");
        let (Some(u), Some(m), Some(r)) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        let (Ok(u), Ok(m), Ok(r)) = (u.parse::<u32>(), m.parse::<u32>(), r.parse::<f32>())
        else {
            continue;
        };
        raw.push((m, u, r)); // movies are rows
    }
    // densify ids
    let mut movie_ids: Vec<u32> = raw.iter().map(|t| t.0).collect();
    movie_ids.sort_unstable();
    movie_ids.dedup();
    let mut user_ids: Vec<u32> = raw.iter().map(|t| t.1).collect();
    user_ids.sort_unstable();
    user_ids.dedup();
    let midx: std::collections::HashMap<u32, u32> =
        movie_ids.iter().enumerate().map(|(i, &m)| (m, i as u32)).collect();
    let uidx: std::collections::HashMap<u32, u32> =
        user_ids.iter().enumerate().map(|(i, &u)| (u, i as u32)).collect();
    let mut triplets: Vec<(u32, u32, f32)> =
        raw.into_iter().map(|(m, u, r)| (midx[&m], uidx[&u], r)).collect();
    Csr::from_triplets(movie_ids.len(), user_ids.len(), &mut triplets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alias_sampler_matches_weights() {
        let mut rng = Rng::seed_from(1);
        let w = [0.1, 0.2, 0.3, 0.4];
        let alias = Alias::new(&w);
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[alias.sample(&mut rng) as usize] += 1;
        }
        for i in 0..4 {
            let got = counts[i] as f64 / n as f64;
            assert!((got - w[i]).abs() < 0.01, "i={i} {got}");
        }
    }

    #[test]
    fn generator_hits_target_stats() {
        let m = movielens_like(0.02, 8, 2);
        assert!(m.rows() >= 8 && m.cols() >= 8);
        // hit at least 90% of the target nnz
        let target = (ML10M_RATINGS as f64 * 0.02 * 0.02) as usize;
        assert!(
            m.nnz() as f64 > 0.9 * target as f64,
            "nnz {} target {target}",
            m.nnz()
        );
        // ratings on the half-star scale in [0.5, 5]
        let mut all_ok = true;
        for i in 0..m.rows() {
            for (_, v) in m.row(i) {
                all_ok &= (0.5..=5.0).contains(&v) && (v * 2.0).fract() == 0.0;
            }
        }
        assert!(all_ok);
        // global mean in a plausible MovieLens band
        assert!((2.5..=4.5).contains(&m.mean()), "{}", m.mean());
    }

    #[test]
    fn popularity_is_long_tailed() {
        let mut rng = Rng::seed_from(3);
        let w = popularity(1000, 0.8, 10.0, &mut rng);
        let mut sorted = w.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let top100: f64 = sorted[..100].iter().sum();
        assert!(top100 > 0.2, "head mass {top100}"); // concentrated head
        assert!(top100 < 0.9); // but not degenerate
    }

    #[test]
    fn deterministic() {
        let a = movielens_like(0.01, 4, 5);
        let b = movielens_like(0.01, 4, 5);
        assert_eq!(a.nnz(), b.nnz());
        let ra: Vec<_> = a.row(0).collect();
        let rb: Vec<_> = b.row(0).collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn loader_parses_dat_format() {
        let dir = std::env::temp_dir().join("psgld_ml_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ratings.dat");
        std::fs::write(&path, "1::10::4.5::123\n2::10::3::124\n1::20::5::125\n").unwrap();
        let m = load_movielens(&path).unwrap();
        assert_eq!(m.rows(), 2); // movies 10, 20
        assert_eq!(m.cols(), 2); // users 1, 2
        assert_eq!(m.nnz(), 3);
    }
}
