//! Ziggurat standard-normal sampler (Marsaglia & Tsang 2000, 128
//! layers) — the §Perf replacement for Box-Muller on the Langevin-noise
//! hot path (no `ln`/`sin`/`cos` on the ~98.8% fast path; one u64 draw
//! per sample).

use super::Rng;

const C: usize = 128;
const R: f64 = 3.442_619_855_899;
const V: f64 = 9.912_563_035_262_17e-3;

struct Tables {
    /// Layer right edges x[0] > x[1] = R > ... > x[128] = 0.
    x: [f64; C + 1],
    /// Fast-path ratios x[i+1]/x[i].
    ratio: [f64; C],
    /// Density at the edges, f(x[i]) = exp(-x[i]^2/2).
    f: [f64; C + 1],
}

fn density(x: f64) -> f64 {
    (-0.5 * x * x).exp()
}

static TABLES: std::sync::OnceLock<Tables> = std::sync::OnceLock::new();

fn tables() -> &'static Tables {
    TABLES.get_or_init(|| {
        let mut x = [0.0; C + 1];
        x[0] = V / density(R); // base layer effective width
        x[1] = R;
        for i in 2..C {
            // invert: area of layer i is V = x[i-1] (f(x[i]) - f(x[i-1]))
            let fx = V / x[i - 1] + density(x[i - 1]);
            x[i] = (-2.0 * fx.ln()).sqrt();
        }
        x[C] = 0.0;
        let mut ratio = [0.0; C];
        let mut f = [0.0; C + 1];
        for i in 0..C {
            ratio[i] = x[i + 1] / x[i];
        }
        for i in 0..=C {
            f[i] = density(x[i]);
        }
        Tables { x, ratio, f }
    })
}

/// One draw from a resolved table reference. [`normal_ziggurat`] and
/// [`fill_normal_ziggurat`] both go through here, so they consume the
/// RNG stream identically — a chain that switches between per-draw and
/// batched noise stays bitwise reproducible.
#[inline]
fn sample(t: &Tables, rng: &mut Rng) -> f64 {
    loop {
        let bits = rng.next_u64();
        let i = (bits & (C as u64 - 1)) as usize;
        // signed uniform in (-1, 1) from the top 52 bits
        let u = ((bits >> 12) as f64) * (2.0 / (1u64 << 52) as f64) - 1.0;
        if u.abs() < t.ratio[i] {
            // fully inside the layer: accept (~98.8% of draws)
            return u * t.x[i];
        }
        if i == 0 {
            // tail beyond R (Marsaglia's exponential trick)
            loop {
                let x = -rng.next_f64_open().ln() / R;
                let y = -rng.next_f64_open().ln();
                if y + y > x * x {
                    return if u < 0.0 { -(R + x) } else { R + x };
                }
            }
        }
        // wedge: uniform y inside the layer's vertical span
        let xx = u * t.x[i];
        let y = t.f[i] + rng.next_f64() * (t.f[i + 1] - t.f[i]);
        if y < density(xx) {
            return xx;
        }
    }
}

/// One standard-normal draw via the ziggurat.
#[inline]
pub fn normal_ziggurat(rng: &mut Rng) -> f64 {
    sample(tables(), rng)
}

/// Fill `out` with standard-normal f32 draws. The table lookup is hoisted
/// out of the loop and the (rare) slow paths stay out of the caller's
/// instruction stream, which is what makes the SGLD noise slab refill
/// cheap; draw `i` is exactly `normal_ziggurat` draw `i` narrowed to f32.
pub fn fill_normal_ziggurat(rng: &mut Rng, out: &mut [f32]) {
    let t = tables();
    for o in out.iter_mut() {
        *o = sample(t, rng) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_invariants() {
        let t = tables();
        // strictly decreasing edges, x[1] = R, x[C] = 0
        assert!((t.x[1] - R).abs() < 1e-12);
        assert_eq!(t.x[C], 0.0);
        for i in 0..C {
            assert!(t.x[i] > t.x[i + 1], "x[{i}]");
            assert!(t.ratio[i] < 1.0);
        }
        // every layer has area V (conservation check)
        for i in 1..C {
            let area = t.x[i] * (density(t.x[i + 1]) - density(t.x[i]));
            assert!((area - V).abs() < 1e-9, "layer {i} area {area}");
        }
    }

    #[test]
    fn fill_matches_per_draw_stream_bitwise() {
        // enough draws to hit the wedge and tail slow paths too
        let n = 100_000;
        let mut r1 = Rng::seed_from(123);
        let mut r2 = Rng::seed_from(123);
        let mut batched = vec![0f32; n];
        fill_normal_ziggurat(&mut r1, &mut batched);
        for (i, &b) in batched.iter().enumerate() {
            let single = normal_ziggurat(&mut r2) as f32;
            assert!(single.to_bits() == b.to_bits(), "draw {i}: {single} vs {b}");
        }
        // and the streams end in the same state
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn moments_match_standard_normal() {
        let mut rng = Rng::seed_from(77);
        let n = 400_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = normal_ziggurat(&mut rng);
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01, "mean {}", s1 / nf);
        assert!((s2 / nf - 1.0).abs() < 0.01, "var {}", s2 / nf);
        assert!((s3 / nf).abs() < 0.03, "skew {}", s3 / nf);
        assert!((s4 / nf - 3.0).abs() < 0.08, "kurtosis {}", s4 / nf);
    }

    #[test]
    fn tail_probabilities() {
        // P(|X| > 2) = 0.0455, P(|X| > 3) = 0.0027, P(X > 3.5) = 2.3e-4:
        // exercises both the wedge and the beyond-R tail path.
        let mut rng = Rng::seed_from(78);
        let n = 1_000_000;
        let (mut p2, mut p3, mut p35) = (0usize, 0usize, 0usize);
        for _ in 0..n {
            let x = normal_ziggurat(&mut rng);
            if x.abs() > 2.0 {
                p2 += 1;
            }
            if x.abs() > 3.0 {
                p3 += 1;
            }
            if x > 3.5 {
                p35 += 1;
            }
        }
        let f = |c: usize| c as f64 / n as f64;
        assert!((f(p2) - 0.0455).abs() < 0.002, "{}", f(p2));
        assert!((f(p3) - 0.0027).abs() < 4e-4, "{}", f(p3));
        assert!((f(p35) - 2.33e-4).abs() < 1e-4, "{}", f(p35));
    }

    #[test]
    fn histogram_matches_density() {
        // coarse chi-square-style check over [-3, 3]
        let mut rng = Rng::seed_from(79);
        let n = 500_000;
        let bins = 24;
        let mut counts = vec![0usize; bins];
        for _ in 0..n {
            let x = normal_ziggurat(&mut rng);
            if (-3.0..3.0).contains(&x) {
                counts[((x + 3.0) / 0.25) as usize] += 1;
            }
        }
        for (b, &c) in counts.iter().enumerate() {
            let lo = -3.0 + b as f64 * 0.25;
            // midpoint-rule expected probability
            let p = density(lo + 0.125) / (2.0 * std::f64::consts::PI).sqrt() * 0.25;
            let got = c as f64 / n as f64;
            assert!(
                (got - p).abs() < 0.15 * p + 2e-4,
                "bin {b}: {got} vs {p}"
            );
        }
    }
}
