//! Deterministic, splittable pseudo-random number generation.
//!
//! Everything stochastic in the crate flows through [`Rng`], a
//! xoshiro256++ generator seeded via SplitMix64, so every experiment is
//! exactly reproducible from a single `u64` seed. Streams for parallel
//! workers are derived with [`Rng::derive`], which hashes a tag chain —
//! the native analogue of `jax.random.fold_in` used on the HLO side.

pub mod dist;
pub mod gauss;

pub use dist::Dist;
pub use gauss::{fill_normal_ziggurat, normal_ziggurat};

/// SplitMix64 step — used for seeding and for tag hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes
/// BigCrush; plenty for MCMC noise injection.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single `u64` via SplitMix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream from `seed` and a tag chain, e.g.
    /// `Rng::derive(seed, &[iteration, block])`. Mirrors
    /// `jax.random.fold_in` semantics (not bit-compatible).
    pub fn derive(seed: u64, tags: &[u64]) -> Self {
        let mut sm = seed;
        let mut acc = splitmix64(&mut sm);
        for &t in tags {
            let mut x = acc ^ t.wrapping_mul(0x2545_F491_4F6C_DD1D);
            acc = splitmix64(&mut x);
        }
        Rng::seed_from(acc)
    }

    /// Split off a child generator (advances `self`).
    pub fn split(&mut self) -> Rng {
        let mut sm = self.next_u64();
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a `ln()` argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Two u32 words of key material for the HLO threefry seed input.
    pub fn seed_words(&mut self) -> [u32; 2] {
        let x = self.next_u64();
        [(x >> 32) as u32, x as u32]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn derive_is_deterministic_and_tag_sensitive() {
        let a = Rng::derive(7, &[1, 2]).next_u64_test();
        let b = Rng::derive(7, &[1, 2]).next_u64_test();
        let c = Rng::derive(7, &[2, 1]).next_u64_test();
        let d = Rng::derive(8, &[1, 2]).next_u64_test();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    impl Rng {
        fn next_u64_test(mut self) -> u64 {
            self.next_u64()
        }
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::seed_from(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_f64();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Rng::seed_from(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn open_unit_never_zero() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..100_000 {
            assert!(rng.next_f64_open() > 0.0);
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::seed_from(6);
        let mut b = a.split();
        let mut c = a.split();
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(y, z);
    }
}
