//! Exact samplers for the distributions the paper's models need:
//! normal, exponential, gamma, Poisson, binomial, multinomial, Dirichlet
//! and the Tweedie compound-Poisson (1 < p < 2).
//!
//! All samplers are exact (rejection/inversion), not approximations —
//! the Gibbs comparator's correctness depends on it.

use super::Rng;

/// Distribution sampling methods on top of [`Rng`].
pub trait Dist {
    /// Standard normal via the Marsaglia polar method.
    fn normal(&mut self) -> f64;
    /// Normal with mean/sd.
    fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }
    /// Exponential with *rate* `lambda` (mean `1/lambda`).
    fn exponential(&mut self, lambda: f64) -> f64;
    /// Gamma with shape `alpha` and *scale* `theta` (mean `alpha*theta`).
    fn gamma(&mut self, alpha: f64, theta: f64) -> f64;
    /// Poisson with mean `lambda`.
    fn poisson(&mut self, lambda: f64) -> u64;
    /// Binomial(n, p).
    fn binomial(&mut self, n: u64, p: f64) -> u64;
    /// Multinomial(n, weights) — `out[k]` counts; weights need not sum to 1.
    fn multinomial(&mut self, n: u64, weights: &[f64], out: &mut [u64]);
    /// Tweedie compound Poisson-gamma with mean `mu`, dispersion `phi`,
    /// power `p in (1,2)` (β-divergence β = 2 − p).
    fn tweedie_cp(&mut self, mu: f64, phi: f64, p: f64) -> f64;
    /// Fill a slice with N(mean, sd) f32 values (hot path for Langevin
    /// noise): Box-Muller in pairs, no per-call branch misprediction.
    fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, sd: f32);
}

impl Dist for Rng {
    fn normal(&mut self) -> f64 {
        // ziggurat (exact; see rng::gauss) — §Perf: ~4x the polar
        // method's throughput, no ln/sqrt on the fast path.
        super::gauss::normal_ziggurat(self)
    }

    fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.next_f64_open().ln() / lambda
    }

    fn gamma(&mut self, alpha: f64, theta: f64) -> f64 {
        debug_assert!(alpha > 0.0 && theta > 0.0);
        if alpha < 1.0 {
            // Boost: X_a = X_{a+1} * U^{1/a}
            let u = self.next_f64_open();
            return self.gamma(alpha + 1.0, theta) * u.powf(1.0 / alpha);
        }
        // Marsaglia & Tsang (2000) squeeze-rejection.
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let t = 1.0 + c * x;
            if t <= 0.0 {
                continue;
            }
            let v = t * t * t;
            let u = self.next_f64_open();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v * theta;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
                return d * v * theta;
            }
        }
    }

    fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 10.0 {
            // Knuth multiplication method.
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // PTRS transformed rejection (Hörmann 1993) — exact for λ ≥ 10.
        let slam = lambda.sqrt();
        let loglam = lambda.ln();
        let b = 0.931 + 2.53 * slam;
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let vr = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.next_f64() - 0.5;
            let v = self.next_f64_open();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
            if us >= 0.07 && v <= vr && k >= 0.0 {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            let lhs = (v * inv_alpha / (a / (us * us) + b)).ln();
            let rhs = -lambda + k * loglam - ln_factorial(k as u64);
            if lhs <= rhs {
                return k as u64;
            }
        }
    }

    fn binomial(&mut self, n: u64, p: f64) -> u64 {
        debug_assert!((0.0..=1.0).contains(&p));
        if n == 0 || p == 0.0 {
            return 0;
        }
        if p == 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let np = n as f64 * p;
        if np < 10.0 {
            // Inversion by sequential search from 0.
            let q = 1.0 - p;
            let s = p / q;
            let mut f = q.powi(n as i32);
            if f <= 0.0 {
                // extreme underflow fallback: normal approximation is
                // unreachable here because np < 10 keeps f representable
                // unless n is astronomically large with tiny p.
                return btrs(self, n, p);
            }
            let u0 = self.next_f64();
            let mut u = u0;
            let mut k = 0u64;
            loop {
                if u <= f {
                    return k;
                }
                u -= f;
                k += 1;
                if k > n {
                    // numeric tail leak; clamp
                    return n;
                }
                f *= s * (n - k + 1) as f64 / k as f64;
            }
        }
        btrs(self, n, p)
    }

    fn multinomial(&mut self, n: u64, weights: &[f64], out: &mut [u64]) {
        debug_assert_eq!(weights.len(), out.len());
        let mut rest: f64 = weights.iter().sum();
        let mut remaining = n;
        for k in 0..weights.len() {
            if remaining == 0 {
                out[k] = 0;
                continue;
            }
            if k + 1 == weights.len() {
                out[k] = remaining;
                remaining = 0;
                continue;
            }
            let p = if rest > 0.0 {
                (weights[k] / rest).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let draw = self.binomial(remaining, p);
            out[k] = draw;
            remaining -= draw;
            rest -= weights[k];
        }
        debug_assert_eq!(remaining, 0);
    }

    fn tweedie_cp(&mut self, mu: f64, phi: f64, p: f64) -> f64 {
        debug_assert!(p > 1.0 && p < 2.0);
        // Compound Poisson-gamma representation: v = Σ_{i<N} G_i with
        // N ~ Po(λ), G ~ Gamma(α, θ):
        //   λ = μ^{2−p} / (φ (2−p)),  α = (2−p)/(p−1),  θ = φ (p−1) μ^{p−1}
        let lambda = mu.powf(2.0 - p) / (phi * (2.0 - p));
        let alpha = (2.0 - p) / (p - 1.0);
        let theta = phi * (p - 1.0) * mu.powf(p - 1.0);
        let n = self.poisson(lambda);
        if n == 0 {
            return 0.0;
        }
        // Sum of n iid Gamma(α, θ) = Gamma(nα, θ).
        self.gamma(n as f64 * alpha, theta)
    }

    fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, sd: f32) {
        for o in out.iter_mut() {
            *o = mean + sd * super::gauss::normal_ziggurat(self) as f32;
        }
    }
}

/// BTRS transformed-rejection binomial sampler (Hörmann 1993), exact for
/// n·p ≥ 10 with p ≤ 0.5.
fn btrs(rng: &mut Rng, n: u64, p: f64) -> u64 {
    let nf = n as f64;
    let q = 1.0 - p;
    let spq = (nf * p * q).sqrt();
    let b = 1.15 + 2.53 * spq;
    let a = -0.0873 + 0.0248 * b + 0.01 * p;
    let c = nf * p + 0.5;
    let vr = 0.92 - 4.2 / b;
    let alpha = (2.83 + 5.1 / b) * spq;
    let lpq = (p / q).ln();
    let m = ((nf + 1.0) * p).floor();
    loop {
        let u = rng.next_f64() - 0.5;
        let v = rng.next_f64_open();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + c).floor();
        if k < 0.0 || k > nf {
            continue;
        }
        if us >= 0.07 && v <= vr {
            return k as u64;
        }
        let vl = (v * alpha / (a / (us * us) + b)).ln();
        // accept iff vl <= ln f(k) - ln f(m), f = binomial pmf (mode m)
        let rhs = (k - m) * lpq
            + (ln_factorial(m as u64) + ln_factorial(n - m as u64))
            - (ln_factorial(k as u64) + ln_factorial(n - k as u64));
        if vl <= rhs {
            return k as u64;
        }
    }
}

/// ln(k!) via lookup for small k, Stirling series beyond.
pub fn ln_factorial(k: u64) -> f64 {
    const TABLE_N: usize = 128;
    static TABLE: std::sync::OnceLock<[f64; TABLE_N]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0.0; TABLE_N];
        for i in 2..TABLE_N {
            t[i] = t[i - 1] + (i as f64).ln();
        }
        t
    });
    if (k as usize) < TABLE_N {
        return table[k as usize];
    }
    let x = k as f64 + 1.0;
    // Stirling's series for ln Γ(x)
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    (x - 0.5) * x.ln() - x + 0.5 * (std::f64::consts::TAU).ln()
        + inv * (1.0 / 12.0 - inv2 * (1.0 / 360.0 - inv2 / 1260.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: impl Iterator<Item = f64>) -> (f64, f64, usize) {
        let mut n = 0usize;
        let (mut s, mut s2) = (0.0, 0.0);
        for x in samples {
            n += 1;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        (mean, s2 / n as f64 - mean * mean, n)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(10);
        let (m, v, _) = moments((0..200_000).map(|_| rng.normal()));
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((v - 1.0).abs() < 0.02, "var {v}");
    }

    #[test]
    fn fill_normal_f32_moments() {
        let mut rng = Rng::seed_from(11);
        let mut buf = vec![0f32; 200_001]; // odd length hits the tail path
        rng.fill_normal_f32(&mut buf, 2.0, 3.0);
        let (m, v, _) = moments(buf.iter().map(|&x| x as f64));
        assert!((m - 2.0).abs() < 0.03, "mean {m}");
        assert!((v - 9.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Rng::seed_from(12);
        let lam = 2.5;
        let (m, v, _) = moments((0..200_000).map(|_| rng.exponential(lam)));
        assert!((m - 1.0 / lam).abs() < 0.005, "mean {m}");
        assert!((v - 1.0 / (lam * lam)).abs() < 0.01, "var {v}");
    }

    #[test]
    fn gamma_moments_shape_above_and_below_one() {
        let mut rng = Rng::seed_from(13);
        for &(a, th) in &[(0.5, 2.0), (1.0, 1.0), (3.7, 0.5), (20.0, 0.1)] {
            let (m, v, _) = moments((0..200_000).map(|_| rng.gamma(a, th)));
            let (em, ev) = (a * th, a * th * th);
            assert!((m - em).abs() < 0.03 * em.max(0.3), "gamma({a},{th}) mean {m} vs {em}");
            assert!((v - ev).abs() < 0.08 * ev.max(0.3), "gamma({a},{th}) var {v} vs {ev}");
        }
    }

    #[test]
    fn poisson_moments_small_and_large_lambda() {
        let mut rng = Rng::seed_from(14);
        for &lam in &[0.3, 3.0, 9.9, 10.1, 47.0, 300.0] {
            let (m, v, _) =
                moments((0..200_000).map(|_| rng.poisson(lam) as f64));
            assert!((m - lam).abs() < 0.02 * lam.max(1.0), "po({lam}) mean {m}");
            assert!((v - lam).abs() < 0.06 * lam.max(1.0), "po({lam}) var {v}");
        }
    }

    #[test]
    fn binomial_moments_all_regimes() {
        let mut rng = Rng::seed_from(15);
        for &(n, p) in &[(5u64, 0.3), (40, 0.1), (100, 0.5), (1000, 0.02), (1000, 0.7)] {
            let (m, v, _) =
                moments((0..100_000).map(|_| rng.binomial(n, p) as f64));
            let (em, ev) = (n as f64 * p, n as f64 * p * (1.0 - p));
            assert!((m - em).abs() < 0.03 * em.max(1.0), "bin({n},{p}) mean {m} vs {em}");
            assert!((v - ev).abs() < 0.08 * ev.max(1.0), "bin({n},{p}) var {v} vs {ev}");
        }
    }

    #[test]
    fn binomial_bounds() {
        let mut rng = Rng::seed_from(16);
        for _ in 0..10_000 {
            let x = rng.binomial(17, 0.4);
            assert!(x <= 17);
        }
        assert_eq!(rng.binomial(9, 0.0), 0);
        assert_eq!(rng.binomial(9, 1.0), 9);
        assert_eq!(rng.binomial(0, 0.5), 0);
    }

    #[test]
    fn multinomial_counts_sum_and_means() {
        let mut rng = Rng::seed_from(17);
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut tot = [0u64; 4];
        let reps = 20_000;
        let n = 50;
        let mut out = [0u64; 4];
        for _ in 0..reps {
            rng.multinomial(n, &w, &mut out);
            assert_eq!(out.iter().sum::<u64>(), n);
            for k in 0..4 {
                tot[k] += out[k];
            }
        }
        let wsum: f64 = w.iter().sum();
        for k in 0..4 {
            let em = n as f64 * w[k] / wsum;
            let m = tot[k] as f64 / reps as f64;
            assert!((m - em).abs() < 0.05 * em, "k={k} {m} vs {em}");
        }
    }

    #[test]
    fn multinomial_zero_weights() {
        let mut rng = Rng::seed_from(18);
        let mut out = [0u64; 3];
        rng.multinomial(10, &[0.0, 1.0, 0.0], &mut out);
        assert_eq!(out, [0, 10, 0]);
    }

    #[test]
    fn tweedie_cp_moments_and_mass_at_zero() {
        let mut rng = Rng::seed_from(19);
        let (mu, phi, p) = (2.0, 1.0, 1.5);
        let mut zeros = 0usize;
        let (m, v, n) = moments((0..200_000).map(|_| {
            let x = rng.tweedie_cp(mu, phi, p);
            if x == 0.0 {
                zeros += 1;
            }
            x
        }));
        // Tweedie: E[V] = μ, Var[V] = φ μ^p
        assert!((m - mu).abs() < 0.02 * mu, "mean {m}");
        let ev = phi * mu.powf(p);
        assert!((v - ev).abs() < 0.05 * ev, "var {v} vs {ev}");
        // P(V=0) = exp(-λ), λ = μ^{2-p}/(φ(2-p)) = sqrt(2)/0.5
        let lam = mu.powf(2.0 - p) / (phi * (2.0 - p));
        let p0 = (-lam).exp();
        let got = zeros as f64 / n as f64;
        assert!((got - p0).abs() < 0.01, "p0 {got} vs {p0}");
    }

    #[test]
    fn ln_factorial_values() {
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-12);
        // Stirling branch vs sum
        let direct: f64 = (2..=200u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(200) - direct).abs() < 1e-9);
    }
}
