//! # PSGLD — Parallel Stochastic Gradient MCMC for Matrix Factorisation
//!
//! A production reproduction of Şimşekli et al. (2015), *"Parallel
//! Stochastic Gradient Markov Chain Monte Carlo for Matrix Factorisation
//! Models"*, as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: grid
//!   partitioning of the observed matrix, part scheduling, the parallel
//!   block-SGLD driver, a discrete-event cluster simulator implementing
//!   the paper's ring communication mechanism (Fig. 4), all comparator
//!   samplers (LD, SGLD, Gibbs, DSGD, DSGLD), metrics and the CLI.
//! * **Layer 2 (python/compile/model.py)** — the Tweedie-NMF update
//!   rules in JAX, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — the Pallas β-divergence
//!   gradient kernel the L2 functions call.
//!
//! The native Rust path is the default and is self-contained: the
//! shared-memory sampler runs the cache-tiled kernels of [`kernels`] on
//! a persistent worker pool ([`util::parallel`]) with zero steady-state
//! heap allocations. The compiled artifacts in `artifacts/` are loaded
//! at runtime through [`runtime`] (PJRT CPU via the `xla` crate, behind
//! the `xla` cargo feature — off by default since that crate cannot be
//! built offline); Python never runs on the sampling path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use psgld::config::{ModelConfig, RunConfig};
//! use psgld::data::synth;
//! use psgld::samplers::Psgld;
//!
//! let model = ModelConfig::poisson(16);
//! let data = synth::poisson_nmf(128, 128, &model, 7);
//! let run = RunConfig::quick(200);
//! let mut sampler = Psgld::new(&data.v, &model, 4, run.clone(), 42);
//! let result = sampler.run(&run);
//! println!("final loglik = {}", result.trace.last_value());
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod monitor;
pub mod obs;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod util;
pub mod samplers;

pub use error::{Error, Result};
