//! Grid partitioning of the observed matrix into blocks, and part
//! (generalized-diagonal) scheduling — Definitions 1 & 2 of the paper.
//!
//! A `GridPartition` splits `[I]` and `[J]` into `B` contiguous pieces
//! each. A [`Part`] is a permutation `σ`: block `b` pairs row-stripe `b`
//! with column-stripe `σ(b)`; all `B` blocks of a part are mutually
//! disjoint in both dimensions, so their factor updates commute and run
//! in parallel. The cyclic family `σ_p(b) = (b + p) mod B` gives `B`
//! non-overlapping parts whose union tiles `V` exactly — satisfying
//! Condition 2 (each part chosen with probability ∝ its size).

use crate::rng::Rng;
use crate::{Error, Result};

/// Equal-as-possible contiguous partition of `[I]` and `[J]` into `B`
/// pieces each, defining the `B×B` block grid.
#[derive(Clone, Debug, PartialEq)]
pub struct GridPartition {
    rows: usize,
    cols: usize,
    b: usize,
    row_bounds: Vec<usize>,
    col_bounds: Vec<usize>,
}

fn bounds(n: usize, b: usize) -> Vec<usize> {
    // piece i gets floor(n/b) + (i < n mod b) elements
    let base = n / b;
    let extra = n % b;
    let mut out = Vec::with_capacity(b + 1);
    let mut acc = 0;
    out.push(0);
    for i in 0..b {
        acc += base + usize::from(i < extra);
        out.push(acc);
    }
    out
}

impl GridPartition {
    /// Create a `B×B` grid over a `rows × cols` matrix.
    pub fn new(rows: usize, cols: usize, b: usize) -> Result<Self> {
        if b == 0 || b > rows || b > cols {
            return Err(Error::Config(format!(
                "B={b} must be in [1, min(I={rows}, J={cols})]"
            )));
        }
        Ok(GridPartition {
            rows,
            cols,
            b,
            row_bounds: bounds(rows, b),
            col_bounds: bounds(cols, b),
        })
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.b
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row index range of row-stripe `bi`.
    #[inline]
    pub fn row_range(&self, bi: usize) -> std::ops::Range<usize> {
        self.row_bounds[bi]..self.row_bounds[bi + 1]
    }

    /// Column index range of column-stripe `bj`.
    #[inline]
    pub fn col_range(&self, bj: usize) -> std::ops::Range<usize> {
        self.col_bounds[bj]..self.col_bounds[bj + 1]
    }

    /// Shape of block `(bi, bj)`.
    pub fn block_shape(&self, bi: usize, bj: usize) -> (usize, usize) {
        (self.row_range(bi).len(), self.col_range(bj).len())
    }

    /// True iff every block has the same shape (needed for the batched
    /// HLO dispatch; holds when `B | I` and `B | J`).
    pub fn uniform_blocks(&self) -> bool {
        self.rows % self.b == 0 && self.cols % self.b == 0
    }

    /// Which stripe a row belongs to.
    pub fn row_stripe_of(&self, i: usize) -> usize {
        debug_assert!(i < self.rows);
        match self.row_bounds.binary_search(&i) {
            Ok(b) => b.min(self.b - 1),
            Err(ins) => ins - 1,
        }
    }

    /// Which stripe a column belongs to.
    pub fn col_stripe_of(&self, j: usize) -> usize {
        debug_assert!(j < self.cols);
        match self.col_bounds.binary_search(&j) {
            Ok(b) => b.min(self.b - 1),
            Err(ins) => ins - 1,
        }
    }

    /// Number of entries of the part with permutation `perm`.
    pub fn part_size(&self, part: &Part) -> usize {
        (0..self.b)
            .map(|b| self.row_range(b).len() * self.col_range(part.perm[b]).len())
            .sum()
    }

    /// `N/|Π|` — the stochastic-gradient bias-correction factor of
    /// Eqs. 8-9 for a *dense* observed matrix.
    pub fn scale_dense(&self, part: &Part) -> f32 {
        (self.rows * self.cols) as f32 / self.part_size(part) as f32
    }
}

/// A part: block `b` covers `row_range(b) × col_range(perm[b])`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Part {
    /// `perm[b]` = column-stripe paired with row-stripe `b`.
    pub perm: Vec<usize>,
}

impl Part {
    /// Identity part `σ(b) = b` — the usual starting point for the
    /// in-place `set_*` updates below.
    pub fn identity(b: usize) -> Self {
        Part { perm: (0..b).collect() }
    }

    /// Cyclic-shift part `σ_p(b) = (b + p) mod B`.
    pub fn cyclic(b: usize, p: usize) -> Self {
        let mut part = Part::identity(b);
        part.set_cyclic(p);
        part
    }

    /// Overwrite in place with the cyclic-shift part `σ_p` (no alloc).
    pub fn set_cyclic(&mut self, p: usize) {
        let b = self.perm.len();
        for (i, v) in self.perm.iter_mut().enumerate() {
            *v = (i + p) % b;
        }
    }

    /// Uniformly random permutation part (DSGD-style stratum).
    pub fn random(b: usize, rng: &mut Rng) -> Self {
        let mut part = Part::identity(b);
        part.set_random(rng);
        part
    }

    /// Overwrite in place with a uniformly random permutation (no
    /// alloc). Consumes exactly the same RNG draws as [`Part::random`].
    pub fn set_random(&mut self, rng: &mut Rng) {
        let b = self.perm.len();
        for (i, v) in self.perm.iter_mut().enumerate() {
            *v = i;
        }
        for i in (1..b).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            self.perm.swap(i, j);
        }
    }

    /// Check the part law: `perm` is a bijection on `0..B`.
    pub fn is_valid(&self) -> bool {
        let b = self.perm.len();
        let mut seen = vec![false; b];
        for &p in &self.perm {
            if p >= b || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        true
    }
}

/// How the coordinator picks the next part.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartSchedule {
    /// Deterministic sweep over the `B` cyclic parts (the paper's choice
    /// in all experiments; satisfies Condition 2 for equal-size parts).
    Cyclic,
    /// Uniformly random cyclic shift each iteration (Condition 2 with
    /// probability ∝ size when stripes are equal).
    RandomShift,
    /// Uniformly random permutation (DSGD stratum sampling; ablation —
    /// the part set is no longer fixed, Condition 2 does not apply).
    RandomPerm,
}

/// Stateful part scheduler.
#[derive(Clone, Debug)]
pub struct PartScheduler {
    schedule: PartSchedule,
    b: usize,
    next_shift: usize,
}

impl PartScheduler {
    pub fn new(schedule: PartSchedule, b: usize) -> Self {
        PartScheduler { schedule, b, next_shift: 0 }
    }

    /// Produce the part for the next iteration.
    pub fn next_part(&mut self, rng: &mut Rng) -> Part {
        let mut part = Part::identity(self.b);
        self.next_part_into(rng, &mut part);
        part
    }

    /// Allocation-free variant: overwrite `part` with the next part.
    /// Consumes exactly the same RNG draws as [`Self::next_part`], so
    /// the two are interchangeable without perturbing the chain.
    pub fn next_part_into(&mut self, rng: &mut Rng, part: &mut Part) {
        debug_assert_eq!(part.perm.len(), self.b);
        match self.schedule {
            PartSchedule::Cyclic => {
                part.set_cyclic(self.next_shift);
                self.next_shift = (self.next_shift + 1) % self.b;
            }
            PartSchedule::RandomShift => {
                part.set_cyclic(rng.next_below(self.b as u64) as usize);
            }
            PartSchedule::RandomPerm => part.set_random(rng),
        }
    }
}

/// Stateless variant of [`PartScheduler`]: overwrite `part` with the
/// part used at (1-based) iteration `t`, given the per-iteration RNG
/// stream (`Rng::derive(seed, &[t, 0xcafe])` in every executor).
///
/// This makes the part a pure function of `(schedule, b, t, seed)`, so
/// asynchronous executors can compute a node's part for any iteration
/// without replaying a stateful scheduler — and it provably matches the
/// stateful path: `Cyclic` uses shift `(t-1) % b` (the scheduler's
/// sweep, which starts at shift 0 for `t = 1`), and the random
/// schedules consume identical draws from the same stream.
pub fn part_at_iter(schedule: PartSchedule, b: usize, t: u64, rng: &mut Rng, part: &mut Part) {
    debug_assert_eq!(part.perm.len(), b);
    debug_assert!(t >= 1, "iterations are 1-based");
    match schedule {
        PartSchedule::Cyclic => part.set_cyclic(((t - 1) % b as u64) as usize),
        PartSchedule::RandomShift => part.set_cyclic(rng.next_below(b as u64) as usize),
        PartSchedule::RandomPerm => part.set_random(rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_equal_split() {
        let g = GridPartition::new(9, 12, 3).unwrap();
        assert_eq!(g.row_range(0), 0..3);
        assert_eq!(g.row_range(2), 6..9);
        assert_eq!(g.col_range(1), 4..8);
        assert!(g.uniform_blocks());
    }

    #[test]
    fn bounds_uneven_split_covers_everything() {
        let g = GridPartition::new(10, 7, 3).unwrap();
        assert!(!g.uniform_blocks());
        let total: usize = (0..3).map(|b| g.row_range(b).len()).sum();
        assert_eq!(total, 10);
        let sizes: Vec<usize> = (0..3).map(|b| g.row_range(b).len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn invalid_b_rejected() {
        assert!(GridPartition::new(4, 4, 0).is_err());
        assert!(GridPartition::new(4, 4, 5).is_err());
    }

    #[test]
    fn stripe_of_inverts_ranges() {
        let g = GridPartition::new(100, 64, 7).unwrap();
        for i in 0..100 {
            let b = g.row_stripe_of(i);
            assert!(g.row_range(b).contains(&i), "row {i} stripe {b}");
        }
        for j in 0..64 {
            let b = g.col_stripe_of(j);
            assert!(g.col_range(b).contains(&j));
        }
    }

    #[test]
    fn cyclic_parts_tile_exactly() {
        // union of the B cyclic parts = all of V, with no overlaps
        let g = GridPartition::new(12, 12, 4).unwrap();
        let mut covered = vec![vec![0u8; 12]; 12];
        for p in 0..4 {
            let part = Part::cyclic(4, p);
            assert!(part.is_valid());
            for b in 0..4 {
                for i in g.row_range(b) {
                    for j in g.col_range(part.perm[b]) {
                        covered[i][j] += 1;
                    }
                }
            }
        }
        assert!(covered.iter().flatten().all(|&c| c == 1));
    }

    #[test]
    fn part_sizes_and_scale() {
        let g = GridPartition::new(12, 12, 4).unwrap();
        let part = Part::cyclic(4, 1);
        assert_eq!(g.part_size(&part), 4 * 9);
        assert_eq!(g.scale_dense(&part), 144.0 / 36.0);
    }

    #[test]
    fn random_part_valid() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..50 {
            assert!(Part::random(6, &mut rng).is_valid());
        }
    }

    #[test]
    fn cyclic_scheduler_sweeps_all_parts() {
        let mut rng = Rng::seed_from(6);
        let mut s = PartScheduler::new(PartSchedule::Cyclic, 3);
        let parts: Vec<Part> = (0..6).map(|_| s.next_part(&mut rng)).collect();
        assert_eq!(parts[0], Part::cyclic(3, 0));
        assert_eq!(parts[1], Part::cyclic(3, 1));
        assert_eq!(parts[2], Part::cyclic(3, 2));
        assert_eq!(parts[3], parts[0]);
    }

    #[test]
    fn next_part_into_matches_next_part_for_every_schedule() {
        for sched in [
            PartSchedule::Cyclic,
            PartSchedule::RandomShift,
            PartSchedule::RandomPerm,
        ] {
            let mut rng_a = Rng::seed_from(11);
            let mut rng_b = Rng::seed_from(11);
            let mut s_a = PartScheduler::new(sched, 5);
            let mut s_b = PartScheduler::new(sched, 5);
            let mut reused = Part::identity(5);
            for step in 0..12 {
                let fresh = s_a.next_part(&mut rng_a);
                s_b.next_part_into(&mut rng_b, &mut reused);
                assert_eq!(fresh, reused, "{sched:?} step {step}");
            }
            // identical RNG consumption: streams still aligned
            assert_eq!(rng_a.next_below(1_000_003), rng_b.next_below(1_000_003));
        }
    }

    #[test]
    fn part_at_iter_matches_stateful_scheduler() {
        // The async executor derives parts statelessly; both paths must
        // agree for every schedule when fed the per-iteration streams
        // the executors actually use.
        for sched in [
            PartSchedule::Cyclic,
            PartSchedule::RandomShift,
            PartSchedule::RandomPerm,
        ] {
            let seed = 42u64;
            let mut sched_state = PartScheduler::new(sched, 4);
            let mut stateful = Part::identity(4);
            let mut stateless = Part::identity(4);
            for t in 1..=13u64 {
                let mut rng_a = Rng::derive(seed, &[t, 0xcafe]);
                let mut rng_b = Rng::derive(seed, &[t, 0xcafe]);
                sched_state.next_part_into(&mut rng_a, &mut stateful);
                part_at_iter(sched, 4, t, &mut rng_b, &mut stateless);
                assert_eq!(stateful, stateless, "{sched:?} t={t}");
            }
        }
    }

    #[test]
    fn random_shift_scheduler_yields_cyclic_parts() {
        let mut rng = Rng::seed_from(7);
        let mut s = PartScheduler::new(PartSchedule::RandomShift, 5);
        for _ in 0..20 {
            let part = s.next_part(&mut rng);
            // must be one of the 5 cyclic parts
            let shift = part.perm[0];
            assert_eq!(part, Part::cyclic(5, shift));
        }
    }
}
