//! Checkpointing: serialize/restore factor state + posterior
//! accumulators so long sampling runs survive restarts — table-stakes
//! for a framework targeting hundreds of millions of entries.
//!
//! Format: a small self-describing binary (magic, version, dims,
//! little-endian f32 payloads) written atomically (temp file + rename).

use std::io::{Read, Write};
use std::path::Path;

use crate::linalg::Mat;
use crate::samplers::FactorState;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"PSGLDCK1";

/// A resumable snapshot of a sampling run.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Iteration the snapshot was taken at.
    pub iteration: u64,
    /// RNG master seed of the run (chains are re-derivable from it).
    pub seed: u64,
    /// Factor state.
    pub state: FactorState,
}

fn write_mat(out: &mut impl Write, m: &Mat) -> Result<()> {
    out.write_all(&(m.rows() as u64).to_le_bytes())?;
    out.write_all(&(m.cols() as u64).to_le_bytes())?;
    for &x in m.as_slice() {
        out.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64(inp: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_mat(inp: &mut impl Read) -> Result<Mat> {
    let rows = read_u64(inp)? as usize;
    let cols = read_u64(inp)? as usize;
    if rows.checked_mul(cols).is_none() || rows * cols > (1 << 33) {
        return Err(Error::Runtime(format!("absurd checkpoint dims {rows}x{cols}")));
    }
    let mut data = vec![0f32; rows * cols];
    let mut buf = [0u8; 4];
    for x in &mut data {
        inp.read_exact(&mut buf)?;
        *x = f32::from_le_bytes(buf);
    }
    Mat::from_vec(rows, cols, data)
}

impl Checkpoint {
    pub fn new(iteration: u64, seed: u64, state: &FactorState) -> Self {
        Checkpoint { iteration, seed, state: state.clone() }
    }

    /// Write atomically to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(MAGIC)?;
            f.write_all(&self.iteration.to_le_bytes())?;
            f.write_all(&self.seed.to_le_bytes())?;
            write_mat(&mut f, &self.state.w)?;
            write_mat(&mut f, &self.state.ht)?;
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Conventional on-disk location for the newest checkpoint in `dir`
    /// (the async cluster executor always overwrites this one file, so
    /// recovery never has to scan the directory).
    pub fn latest_path(dir: &Path) -> std::path::PathBuf {
        dir.join("latest.ckpt")
    }

    /// Load and validate. Any I/O failure mid-payload (short file,
    /// unreadable disk) is rewrapped with the path and a hint that the
    /// file is truncated or corrupted — restores must fail loudly, never
    /// propagate a bare "unexpected EOF".
    pub fn load(path: &Path) -> Result<Self> {
        Self::load_inner(path).map_err(|e| match e {
            Error::Io(io) => Error::Runtime(format!(
                "failed to read checkpoint {}: {io} (file truncated or corrupted? \
                 delete it to restart from scratch)",
                path.display()
            )),
            other => other,
        })
    }

    fn load_inner(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Runtime(format!(
                "{} is not a PSGLD checkpoint (bad magic)",
                path.display()
            )));
        }
        let iteration = read_u64(&mut f)?;
        let seed = read_u64(&mut f)?;
        let w = read_mat(&mut f)?;
        let ht = read_mat(&mut f)?;
        if w.cols() != ht.cols() {
            return Err(Error::Runtime(format!(
                "checkpoint K mismatch: W has {}, Ht has {}",
                w.cols(),
                ht.cols()
            )));
        }
        Ok(Checkpoint { iteration, seed, state: FactorState { w, ht } })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NmfModel;
    use crate::rng::Rng;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("psgld_ckpt_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let model = NmfModel::poisson(3);
        let mut rng = Rng::seed_from(1);
        let state = FactorState::from_prior(&model, 7, 9, &mut rng);
        let ck = Checkpoint::new(1234, 42, &state);
        let path = tmpdir().join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.iteration, 1234);
        assert_eq!(back.seed, 42);
        assert_eq!(back.state.w, state.w);
        assert_eq!(back.state.ht, state.ht);
    }

    #[test]
    fn resume_continues_identically() {
        // run 100 iters; checkpoint at 50; resuming from the checkpoint
        // with the same seed + iteration numbering reproduces the chain
        use crate::config::{RunConfig, StepSchedule};
        use crate::data::synth;
        use crate::samplers::{Psgld, Sampler};

        let model = NmfModel::poisson(3);
        let data = synth::poisson_nmf(16, 16, &model, 5);
        let run = RunConfig::quick(100)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

        let mut full = Psgld::new(&data.v, &model, 2, run.clone(), 9);
        let mut ck = None;
        for t in 1..=100 {
            full.step(t);
            if t == 50 {
                ck = Some(Checkpoint::new(t, 9, full.state()));
            }
        }
        let ck = ck.unwrap();
        let path = tmpdir().join("resume.ckpt");
        ck.save(&path).unwrap();
        let restored = Checkpoint::load(&path).unwrap();

        let mut resumed = Psgld::new(&data.v, &model, 2, run.clone(), restored.seed)
            .with_state(restored.state);
        for t in restored.iteration + 1..=100 {
            resumed.step(t);
        }
        assert_eq!(resumed.state().w, full.state().w);
        assert_eq!(resumed.state().ht, full.state().ht);
    }

    #[test]
    fn rejects_garbage_files() {
        let path = tmpdir().join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err}").contains("magic"));
        assert!(Checkpoint::load(&tmpdir().join("missing.ckpt")).is_err());
    }

    #[test]
    fn truncated_checkpoint_error_is_actionable() {
        // valid header, payload cut short: the error must name the file
        // and say it looks truncated/corrupted, not just "unexpected EOF"
        let model = NmfModel::poisson(2);
        let mut rng = Rng::seed_from(3);
        let state = FactorState::from_prior(&model, 6, 6, &mut rng);
        let dir = tmpdir();
        let path = dir.join("trunc.ckpt");
        Checkpoint::new(10, 1, &state).save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let msg = format!("{}", Checkpoint::load(&path).unwrap_err());
        assert!(msg.contains("trunc.ckpt"), "{msg}");
        assert!(msg.contains("truncated or corrupted"), "{msg}");
    }

    #[test]
    fn latest_path_is_stable() {
        let d = std::path::Path::new("/some/dir");
        assert_eq!(Checkpoint::latest_path(d), d.join("latest.ckpt"));
    }
}
