//! The L3 coordinator over the AOT-compiled HLO executables: drives the
//! PSGLD chain with **one PJRT dispatch per iteration** — the batched
//! part update `[B,m,K]×[B,K,n]×[B,m,n] → (W', H')` — exactly the
//! paper's "one CUDA launch per part" structure, retargeted at XLA.
//!
//! State lives in stacked-block layout ([`StackedBlocks`]); aligning the
//! H column-stripes with the current part's generalized diagonal is a
//! gather by the part permutation (cheap contiguous copies), and the V
//! blocks of every cyclic part are pre-stacked once at construction so
//! the hot loop moves no data-matrix bytes at all.

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use std::path::Path;

use crate::config::RunConfig;
use crate::linalg::{Mat, StackedBlocks};
use crate::model::NmfModel;
use crate::partition::{GridPartition, Part, PartSchedule, PartScheduler};
use crate::rng::Rng;
use crate::runtime::{ArtifactKind, XlaRuntime};
use crate::samplers::{FactorState, Sampler};
use crate::{Error, Result};

/// PSGLD driven through the batched HLO part-update executable.
pub struct HloPsgld {
    runtime: XlaRuntime,
    entry: String,
    loglik_entry: Option<String>,
    model: NmfModel,
    grid: GridPartition,
    /// W row-stripes `[B, m, K]`.
    ws: StackedBlocks,
    /// H column-stripes `[B, K, n]` (slot = stripe index).
    hs: StackedBlocks,
    /// Gather scratch for the part-permuted H stripes.
    hs_gather: StackedBlocks,
    /// Pre-stacked V blocks per cyclic shift: `v_parts[p]` slot `b`
    /// holds block `(b, (b+p) % B)`.
    v_parts: Vec<StackedBlocks>,
    scheduler: PartScheduler,
    run_cfg: RunConfig,
    seed: u64,
    /// Assembled state (refreshed after every step).
    state: FactorState,
    /// Dense V kept for the native monitor fallback.
    v: Mat,
}

impl HloPsgld {
    /// Build from a dense matrix; requires `B | I`, `B | J` and a
    /// matching `part_update` artifact in the manifest.
    pub fn new(
        artifacts: &Path,
        v: &Mat,
        model: &NmfModel,
        b: usize,
        run: RunConfig,
        seed: u64,
    ) -> Result<Self> {
        let grid = GridPartition::new(v.rows(), v.cols(), b)?;
        if !grid.uniform_blocks() {
            return Err(Error::Config(format!(
                "HLO path needs uniform blocks: B={b} must divide I={} and J={}",
                v.rows(),
                v.cols()
            )));
        }
        if matches!(run.schedule, PartSchedule::RandomPerm) {
            return Err(Error::Config(
                "HLO path supports Cyclic/RandomShift schedules (V blocks are \
                 pre-stacked per cyclic part)"
                    .into(),
            ));
        }
        let m = v.rows() / b;
        let n = v.cols() / b;
        let k = model.k;
        let mut runtime = XlaRuntime::new(artifacts)?;
        let entry = runtime
            .manifest()
            .find_part_update(model.beta, b, m, n, k, model.mirror)?
            .name
            .clone();
        runtime.prepare(&entry)?;
        let loglik_entry = runtime
            .manifest()
            .find_full(ArtifactKind::Loglik, model.beta, v.rows(), v.cols(), k)
            .ok()
            .map(|e| e.name.clone());

        let mut rng = Rng::derive(seed, &[0x910_9516]);
        let state = FactorState::from_prior(model, v.rows(), v.cols(), &mut rng);

        // stack W row-stripes and H column-stripes
        let w_blocks: Vec<Mat> =
            (0..b).map(|bi| state.w.slice_block(bi * m, (bi + 1) * m, 0, k)).collect();
        let h = state.h();
        let h_blocks: Vec<Mat> =
            (0..b).map(|bj| h.slice_block(0, k, bj * n, (bj + 1) * n)).collect();

        // pre-stack the V blocks of each cyclic part
        let v_parts: Vec<StackedBlocks> = (0..b)
            .map(|p| {
                let blocks: Vec<Mat> = (0..b)
                    .map(|bi| {
                        let bj = (bi + p) % b;
                        v.slice_block(bi * m, (bi + 1) * m, bj * n, (bj + 1) * n)
                    })
                    .collect();
                StackedBlocks::from_blocks(&blocks)
            })
            .collect::<Result<_>>()?;

        Ok(HloPsgld {
            runtime,
            entry,
            loglik_entry,
            model: model.clone(),
            scheduler: PartScheduler::new(run.schedule, b),
            run_cfg: run,
            grid,
            ws: StackedBlocks::from_blocks(&w_blocks)?,
            hs: StackedBlocks::from_blocks(&h_blocks)?,
            hs_gather: StackedBlocks::zeros(b, k, n),
            v_parts,
            seed,
            state,
            v: v.clone(),
        })
    }

    pub fn grid(&self) -> &GridPartition {
        &self.grid
    }

    /// Monitor the data log-likelihood through the lowered HLO monitor
    /// when available, otherwise natively.
    pub fn loglik(&mut self) -> f64 {
        let h = self.state.h();
        if let Some(name) = self.loglik_entry.clone() {
            let dims = (self.grid.rows(), self.grid.cols(), self.model.k);
            if let Ok(ll) = self.runtime.loglik(
                &name,
                self.state.w.as_slice(),
                h.as_slice(),
                self.v.as_slice(),
                dims,
            ) {
                return ll;
            }
        }
        self.model.loglik_dense(&self.state.w, &h, &self.v)
    }

    fn refresh_state(&mut self) {
        self.state.w = self.ws.to_row_stripes();
        self.state.ht = self.hs.to_col_stripes().transpose();
    }

    /// The per-iteration body; split out so `step` stays panic-free at
    /// the trait boundary.
    fn try_step(&mut self, t: u64) -> Result<()> {
        let b = self.grid.b();
        let mut rng = Rng::derive(self.seed, &[t, 0xcafe]);
        let part = self.scheduler.next_part(&mut rng);
        let shift = part.perm[0]; // cyclic parts are determined by the shift
        debug_assert_eq!(part, Part::cyclic(b, shift));
        let eps = self.run_cfg.step.eps(t) as f32;
        let scale = self.grid.scale_dense(&part);
        let seed_words = Rng::derive(self.seed, &[t, 0x5eed]).seed_words();

        // align H stripes with the part diagonal: slot b <- stripe perm[b]
        self.hs.gather_perm_into(&part.perm, &mut self.hs_gather);
        let (ws_next, hs_next) = self.runtime.part_update(
            &self.entry,
            &self.ws,
            &self.hs_gather,
            &self.v_parts[shift],
            eps,
            scale,
            self.model.lam_w,
            self.model.lam_h,
            seed_words,
        )?;
        self.ws = ws_next;
        self.hs.scatter_perm_from(&part.perm, &hs_next);
        self.refresh_state();
        Ok(())
    }
}

impl Sampler for HloPsgld {
    fn step(&mut self, t: u64) {
        self.try_step(t).expect("HLO part update failed");
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "psgld_hlo"
    }
}

/// Full-batch Langevin dynamics through the lowered `ld_update`
/// executable (the HLO twin of [`crate::samplers::Ld`]).
pub struct HloLd {
    runtime: XlaRuntime,
    entry: String,
    model: NmfModel,
    state: FactorState,
    v: Mat,
    eps: f64,
    seed: u64,
}

impl HloLd {
    pub fn new(
        artifacts: &Path,
        v: &Mat,
        model: &NmfModel,
        eps: f64,
        seed: u64,
    ) -> Result<Self> {
        let mut runtime = XlaRuntime::new(artifacts)?;
        let entry = runtime
            .manifest()
            .find_full(ArtifactKind::LdUpdate, model.beta, v.rows(), v.cols(), model.k)?
            .name
            .clone();
        runtime.prepare(&entry)?;
        let mut rng = Rng::derive(seed, &[0x91_01d]);
        let state = FactorState::from_prior(model, v.rows(), v.cols(), &mut rng);
        Ok(HloLd {
            runtime,
            entry,
            model: model.clone(),
            state,
            v: v.clone(),
            eps,
            seed,
        })
    }
}

impl Sampler for HloLd {
    fn step(&mut self, t: u64) {
        let (i, j, k) = self.state.shape();
        let h = self.state.h();
        let seed_words = Rng::derive(self.seed, &[t, 0x5eed]).seed_words();
        let (w2, h2) = self
            .runtime
            .ld_update(
                &self.entry,
                self.state.w.as_slice(),
                h.as_slice(),
                self.v.as_slice(),
                (i, j, k),
                self.eps as f32,
                self.model.lam_w,
                self.model.lam_h,
                seed_words,
            )
            .expect("HLO ld update failed");
        self.state.w = Mat::from_vec(i, k, w2).expect("shape");
        self.state.ht = Mat::from_vec(k, j, h2).expect("shape").transpose();
    }

    fn state(&self) -> &FactorState {
        &self.state
    }

    fn model(&self) -> &NmfModel {
        &self.model
    }

    fn name(&self) -> &'static str {
        "ld_hlo"
    }
}

// Integration tests against the real artifacts live in
// rust/tests/runtime_roundtrip.rs and rust/tests/e2e_samplers.rs.
