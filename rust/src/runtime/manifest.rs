//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. `manifest.json` lists every lowered executable with
//! its kind, hyper-parameters baked at lowering time, and I/O schema.

use std::path::{Path, PathBuf};

use crate::util::Json;
use crate::{Error, Result};

/// Element dtype of an executable input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "u32" => Ok(Dtype::U32),
            other => Err(Error::Runtime(format!("unknown dtype '{other}'"))),
        }
    }
}

/// One named input or output tensor.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(IoSpec {
            name: j.field("name")?.as_str()?.to_string(),
            dtype: Dtype::parse(j.field("dtype")?.as_str()?)?,
            shape: j
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Batched part update `[B,m,K]×[B,K,n]×[B,m,n] → (W', H')`.
    PartUpdate,
    /// Full-matrix Langevin step.
    LdUpdate,
    /// Full-matrix unnormalised log-likelihood.
    Loglik,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "part_update" => Ok(ArtifactKind::PartUpdate),
            "ld_update" => Ok(ArtifactKind::LdUpdate),
            "loglik" => Ok(ArtifactKind::Loglik),
            other => Err(Error::Runtime(format!("unknown artifact kind '{other}'"))),
        }
    }
}

/// One lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub beta: f32,
    pub phi: f32,
    pub mirror: bool,
    /// Part-update batch size (B); 1 for full-matrix kinds.
    pub b: usize,
    /// Block rows (m) or full rows (I).
    pub m: usize,
    /// Block cols (n) or full cols (J).
    pub n: usize,
    pub k: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactEntry {
    fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let kind = ArtifactKind::parse(j.field("kind")?.as_str()?)?;
        let (b, m, n) = match kind {
            ArtifactKind::PartUpdate => (
                j.field("b")?.as_usize()?,
                j.field("m")?.as_usize()?,
                j.field("n")?.as_usize()?,
            ),
            _ => (1, j.field("i")?.as_usize()?, j.field("j")?.as_usize()?),
        };
        Ok(ArtifactEntry {
            name: j.field("name")?.as_str()?.to_string(),
            file: dir.join(j.field("file")?.as_str()?),
            kind,
            beta: j.field("beta")?.as_f64()? as f32,
            phi: j.field("phi")?.as_f64()? as f32,
            mirror: j.field("mirror")?.as_bool()?,
            b,
            m,
            n,
            k: j.field("k")?.as_usize()?,
            inputs: j
                .field("inputs")?
                .as_arr()?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .field("outputs")?
                .as_arr()?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let _io_span = crate::obs::Span::enter(crate::obs::Phase::Io, "manifest_load");
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let version = j.field("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Runtime(format!("unsupported manifest version {version}")));
        }
        let entries = j
            .field("entries")?
            .as_arr()?
            .iter()
            .map(|e| ArtifactEntry::from_json(dir, e))
            .collect::<Result<Vec<_>>>()?;
        crate::log_debug!(
            "manifest: loaded {} artifact entries from {}",
            entries.len(),
            path.display()
        );
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn by_name(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))
    }

    /// Locate a part-update executable for the given geometry/model.
    pub fn find_part_update(
        &self,
        beta: f32,
        b: usize,
        m: usize,
        n: usize,
        k: usize,
        mirror: bool,
    ) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| {
                e.kind == ArtifactKind::PartUpdate
                    && e.beta == beta
                    && e.b == b
                    && e.m == m
                    && e.n == n
                    && e.k == k
                    && e.mirror == mirror
            })
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no part_update artifact for beta={beta} B={b} m={m} n={n} k={k} \
                     mirror={mirror}; add it to aot.py's shape table and re-run \
                     `make artifacts`"
                ))
            })
    }

    /// Locate a full-matrix executable (`ld_update` or `loglik`).
    pub fn find_full(
        &self,
        kind: ArtifactKind,
        beta: f32,
        i: usize,
        j: usize,
        k: usize,
    ) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.beta == beta && e.m == i && e.n == j && e.k == k)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no {kind:?} artifact for beta={beta} I={i} J={j} K={k}"
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let text = r#"{
 "version": 1,
 "entries": [
  {"name": "part_update_b1p0_B2_m4_n4_k2", "file": "pu.hlo.txt",
   "kind": "part_update", "beta": 1.0, "phi": 1.0, "mirror": true,
   "b": 2, "m": 4, "n": 4, "k": 2,
   "inputs": [{"name": "ws", "dtype": "f32", "shape": [2,4,2]}],
   "outputs": [{"name": "ws_next", "dtype": "f32", "shape": [2,4,2]}]},
  {"name": "loglik_b1p0_i8_j8_k2", "file": "ll.hlo.txt",
   "kind": "loglik", "beta": 1.0, "phi": 1.0, "mirror": true,
   "i": 8, "j": 8, "k": 2,
   "inputs": [{"name": "w", "dtype": "f32", "shape": [8,2]}],
   "outputs": [{"name": "ll", "dtype": "f32", "shape": []}]}
 ]
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn load_and_find() {
        let dir = std::env::temp_dir().join("psgld_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let pu = m.find_part_update(1.0, 2, 4, 4, 2, true).unwrap();
        assert_eq!(pu.kind, ArtifactKind::PartUpdate);
        assert_eq!(pu.inputs[0].elements(), 16);
        assert!(m.find_part_update(1.0, 3, 4, 4, 2, true).is_err());
        let ll = m.find_full(ArtifactKind::Loglik, 1.0, 8, 8, 2).unwrap();
        assert_eq!(ll.name, "loglik_b1p0_i8_j8_k2");
        assert!(m.by_name("nope").is_err());
        assert!(m.by_name("loglik_b1p0_i8_j8_k2").is_ok());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent/psgld")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
