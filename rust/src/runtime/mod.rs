//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the PJRT CPU client (via
//! the `xla` crate) and executes them from the sampling hot path.
//!
//! Python never runs here — the artifacts are self-contained HLO
//! programs with the Pallas kernel, the Langevin noise (threefry from a
//! `u32[2]` seed input) and the mirroring step already lowered in.
//!
//! The `xla` crate is an optional dependency (feature `xla`): it cannot
//! be built in offline environments, so without the feature this module
//! compiles a stub [`XlaRuntime`] whose constructor still validates the
//! manifest but then reports that the backend is unavailable. Everything
//! that consumes the runtime (coordinator, tests, benches) gates on
//! `XlaRuntime::new` succeeding, so the native path is unaffected.

pub mod manifest;

pub use manifest::{ArtifactEntry, ArtifactKind, Dtype, IoSpec, Manifest};

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;

use crate::linalg::StackedBlocks;
#[cfg(feature = "xla")]
use crate::Error;
use crate::Result;

/// Compiled-executable cache over the artifact manifest.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Build an `f32` tensor literal from a flat slice + dims.
#[cfg(feature = "xla")]
fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Scalar f32 literal.
#[cfg(feature = "xla")]
fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

/// `u32[2]` seed literal.
#[cfg(feature = "xla")]
fn literal_seed(seed: [u32; 2]) -> xla::Literal {
    xla::Literal::vec1(&seed)
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.by_name(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {:?}", entry.file))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.prepare(name)?;
        Ok(self.cache.get(name).expect("prepared"))
    }

    /// Execute an artifact whose lowered signature returns a tuple;
    /// returns the tuple members as literals.
    fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// One batched part update (paper Eqs. 8-9 over all B blocks of a
    /// part, one dispatch): consumes stacked blocks, returns updated
    /// stacked blocks.
    #[allow(clippy::too_many_arguments)]
    pub fn part_update(
        &mut self,
        entry_name: &str,
        ws: &StackedBlocks,
        hs: &StackedBlocks,
        vs: &StackedBlocks,
        eps: f32,
        scale: f32,
        lam_w: f32,
        lam_h: f32,
        seed: [u32; 2],
    ) -> Result<(StackedBlocks, StackedBlocks)> {
        let [b, m, k] = ws.dims();
        let [b2, k2, n] = hs.dims();
        let [b3, m2, n2] = vs.dims();
        if b != b2 || b != b3 || k != k2 || m != m2 || n != n2 {
            return Err(Error::Shape(format!(
                "part_update dims mismatch: W{:?} H{:?} V{:?}",
                ws.dims(),
                hs.dims(),
                vs.dims()
            )));
        }
        let inputs = vec![
            literal_f32(ws.as_slice(), &[b, m, k])?,
            literal_f32(hs.as_slice(), &[b, k, n])?,
            literal_f32(vs.as_slice(), &[b, m, n])?,
            literal_scalar(eps),
            literal_scalar(scale),
            literal_scalar(lam_w),
            literal_scalar(lam_h),
            literal_seed(seed),
        ];
        let outs = self.execute(entry_name, &inputs)?;
        if outs.len() != 2 {
            return Err(Error::Runtime(format!(
                "part_update returned {} outputs, expected 2",
                outs.len()
            )));
        }
        let mut ws_next = StackedBlocks::zeros(b, m, k);
        ws_next.as_mut_slice().copy_from_slice(&outs[0].to_vec::<f32>()?);
        let mut hs_next = StackedBlocks::zeros(b, k, n);
        hs_next.as_mut_slice().copy_from_slice(&outs[1].to_vec::<f32>()?);
        Ok((ws_next, hs_next))
    }

    /// One full-matrix Langevin step.
    #[allow(clippy::too_many_arguments)]
    pub fn ld_update(
        &mut self,
        entry_name: &str,
        w: &[f32],
        h: &[f32],
        v: &[f32],
        dims: (usize, usize, usize), // (I, J, K)
        eps: f32,
        lam_w: f32,
        lam_h: f32,
        seed: [u32; 2],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (i, j, k) = dims;
        let inputs = vec![
            literal_f32(w, &[i, k])?,
            literal_f32(h, &[k, j])?,
            literal_f32(v, &[i, j])?,
            literal_scalar(eps),
            literal_scalar(lam_w),
            literal_scalar(lam_h),
            literal_seed(seed),
        ];
        let outs = self.execute(entry_name, &inputs)?;
        Ok((outs[0].to_vec::<f32>()?, outs[1].to_vec::<f32>()?))
    }

    /// Full-matrix unnormalised log-likelihood.
    pub fn loglik(
        &mut self,
        entry_name: &str,
        w: &[f32],
        h: &[f32],
        v: &[f32],
        dims: (usize, usize, usize),
    ) -> Result<f64> {
        let (i, j, k) = dims;
        let inputs = vec![
            literal_f32(w, &[i, k])?,
            literal_f32(h, &[k, j])?,
            literal_f32(v, &[i, j])?,
        ];
        let outs = self.execute(entry_name, &inputs)?;
        let v = outs[0].to_vec::<f32>()?;
        Ok(v[0] as f64)
    }
}

/// Stub runtime compiled when the `xla` feature is off: validates the
/// manifest (so error paths stay testable) and then reports that the
/// backend is unavailable. `new` never returns `Ok`, so the remaining
/// methods are unreachable but keep the call sites compiling.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
fn backend_unavailable<T>() -> Result<T> {
    Err(crate::Error::Runtime(
        "XLA/PJRT backend not compiled in — rebuild with `--features xla` \
         (requires the `xla` crate, unavailable offline)"
            .into(),
    ))
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Load the manifest from `dir`, then fail: the PJRT client needs
    /// the `xla` feature.
    pub fn new(dir: &Path) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        backend_unavailable()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn prepare(&mut self, _name: &str) -> Result<()> {
        backend_unavailable()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn part_update(
        &mut self,
        _entry_name: &str,
        _ws: &StackedBlocks,
        _hs: &StackedBlocks,
        _vs: &StackedBlocks,
        _eps: f32,
        _scale: f32,
        _lam_w: f32,
        _lam_h: f32,
        _seed: [u32; 2],
    ) -> Result<(StackedBlocks, StackedBlocks)> {
        backend_unavailable()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn ld_update(
        &mut self,
        _entry_name: &str,
        _w: &[f32],
        _h: &[f32],
        _v: &[f32],
        _dims: (usize, usize, usize),
        _eps: f32,
        _lam_w: f32,
        _lam_h: f32,
        _seed: [u32; 2],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        backend_unavailable()
    }

    pub fn loglik(
        &mut self,
        _entry_name: &str,
        _w: &[f32],
        _h: &[f32],
        _v: &[f32],
        _dims: (usize, usize, usize),
    ) -> Result<f64> {
        backend_unavailable()
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    // Full round-trip tests against real artifacts live in
    // rust/tests/runtime_roundtrip.rs (they need `make artifacts`).

    #[test]
    fn literal_builders() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.element_count(), 6);
        let s = literal_seed([7, 9]);
        assert_eq!(s.to_vec::<u32>().unwrap(), vec![7, 9]);
        let sc = literal_scalar(2.5);
        assert_eq!(sc.get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn stub_reports_missing_backend() {
        // compiled out here; the stub variant is exercised in the
        // default build via `stub_error_mentions_feature` below.
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_error_mentions_feature() {
        // a directory with a valid manifest would still fail with the
        // feature hint; a missing dir fails earlier with the make hint
        let err = XlaRuntime::new(Path::new("/definitely/not/here")).unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
        let err: crate::Error = backend_unavailable::<()>().unwrap_err();
        assert!(format!("{err}").contains("--features xla"));
    }
}
