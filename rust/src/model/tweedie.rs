//! Tweedie / β-divergence math — the Rust mirror of
//! `python/compile/kernels/psgld_grads.py`. The constants and special
//! cases MUST stay in sync with the Python side: the integration tests
//! compare native updates against the HLO executables bit-for-bit-ish
//! (f32 tolerance).

/// Floor added to `mu` everywhere (`beta < 2` divides by powers of mu).
pub const MU_EPS: f32 = 1e-6;

/// Floor for `v` inside `log(v/mu)` when `beta == 0` (Itakura-Saito).
pub const V_EPS: f32 = 1e-12;

/// `mu^(beta-2)` with the special cases the paper uses.
#[inline]
pub fn elementwise_weight(mu: f32, beta: f32) -> f32 {
    if beta == 2.0 {
        1.0
    } else if beta == 1.0 {
        1.0 / mu
    } else if beta == 0.0 {
        1.0 / (mu * mu)
    } else {
        mu.powf(beta - 2.0)
    }
}

/// β-divergence `d_beta(v || mu)` (generalises IS / KL / Euclidean).
#[inline]
pub fn beta_div(v: f32, mu: f32, beta: f32) -> f32 {
    if beta == 1.0 {
        // generalised KL: v log(v/mu) - v + mu, with v=0 -> mu
        let t = if v > 0.0 { v * (v.max(V_EPS) / mu).ln() } else { 0.0 };
        t - v + mu
    } else if beta == 0.0 {
        // Itakura-Saito: v/mu - log(v/mu) - 1
        let vs = v.max(V_EPS);
        vs / mu - (vs / mu).ln() - 1.0
    } else if beta == 2.0 {
        0.5 * (v - mu) * (v - mu)
    } else {
        v.max(0.0).powf(beta) / (beta * (beta - 1.0)) - v * mu.powf(beta - 1.0) / (beta - 1.0)
            + mu.powf(beta) / beta
    }
}

/// Per-entry unnormalised log-likelihood `-d_beta(v||mu)/phi`.
#[inline]
pub fn loglik_entry(v: f32, mu: f32, beta: f32, phi: f32) -> f32 {
    -beta_div(v, mu, beta) / phi
}

/// The gradient "error" factor `e = (v - mu) mu^{beta-2} / phi`;
/// `d loglik / d mu`. Multiply by `d mu / d w = sign(w)|h|` etc.
#[inline]
pub fn grad_error(v: f32, mu: f32, beta: f32, phi: f32) -> f32 {
    (v - mu) * elementwise_weight(mu, beta) / phi
}

/// Tweedie power parameter `p = 2 - beta` (variance `V(mu) = phi mu^p`).
#[inline]
pub fn tweedie_power(beta: f32) -> f32 {
    2.0 - beta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_cases_match_generic_limits() {
        // generic formula at beta close to the special values converges
        for &(beta, v, mu) in &[(1.0f32, 3.0f32, 2.0f32), (2.0, 3.0, 2.0), (0.0, 3.0, 2.0)] {
            let exact = beta_div(v, mu, beta);
            let nearby = beta_div(v, mu, beta + 1e-3);
            assert!(
                (exact - nearby).abs() < 0.02 * exact.abs().max(0.1),
                "beta={beta}: {exact} vs {nearby}"
            );
        }
    }

    #[test]
    fn divergence_nonnegative_and_zero_at_equality() {
        for &beta in &[0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0] {
            for &v in &[0.5f32, 1.0, 4.0] {
                assert!(beta_div(v, v, beta).abs() < 1e-5, "beta={beta} v={v}");
                assert!(beta_div(v, 2.0 * v, beta) > 0.0);
                assert!(beta_div(v, 0.5 * v, beta) > 0.0);
            }
        }
    }

    #[test]
    fn kl_zero_data() {
        // v = 0: d = mu for KL
        assert!((beta_div(0.0, 2.5, 1.0) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn grad_error_sign() {
        for &beta in &[0.0f32, 0.5, 1.0, 2.0] {
            assert!(grad_error(3.0, 2.0, beta, 1.0) > 0.0); // v > mu: push up
            assert!(grad_error(1.0, 2.0, beta, 1.0) < 0.0); // v < mu: push down
            assert_eq!(grad_error(2.0, 2.0, beta, 1.0), 0.0);
        }
    }

    #[test]
    fn grad_is_derivative_of_loglik() {
        // finite differences in mu
        for &beta in &[0.0f32, 0.5, 1.0, 1.5, 2.0] {
            let (v, mu, h) = (3.0f32, 2.0f32, 1e-3f32);
            let fd = (loglik_entry(v, mu + h, beta, 1.0) - loglik_entry(v, mu - h, beta, 1.0))
                / (2.0 * h);
            let an = grad_error(v, mu, beta, 1.0);
            assert!((fd - an).abs() < 1e-2 * an.abs().max(0.1), "beta={beta}: {fd} vs {an}");
        }
    }

    #[test]
    fn phi_scales_inverse() {
        let a = loglik_entry(3.0, 2.0, 1.0, 1.0);
        let b = loglik_entry(3.0, 2.0, 1.0, 2.0);
        assert!((a - 2.0 * b).abs() < 1e-6);
    }

    #[test]
    fn power_mapping() {
        assert_eq!(tweedie_power(1.0), 1.0); // Poisson
        assert_eq!(tweedie_power(2.0), 0.0); // Gaussian
        assert_eq!(tweedie_power(0.0), 2.0); // gamma
        assert_eq!(tweedie_power(0.5), 1.5); // compound Poisson
    }
}
