//! The probabilistic model: Tweedie observation densities / β-divergence
//! and the exponential-prior NMF generative model (paper Eq. 13).

pub mod nmf;
pub mod tweedie;

pub use nmf::NmfModel;
pub use tweedie::{beta_div, elementwise_weight, MU_EPS};
