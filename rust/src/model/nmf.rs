//! The Tweedie-NMF model object (paper Eq. 13): hyper-parameters plus
//! prior sampling / densities over the factor state `(W, H)`.

use crate::linalg::Mat;
use crate::model::tweedie;
use crate::rng::Rng;
use crate::{Error, Result};

/// Hyper-parameters of the Tweedie-NMF model
/// `p(V|WH) = Π TW(v; Σ_k |w||h|, phi, beta)`, `p(w) = E(w; lam_w)`,
/// `p(h) = E(h; lam_h)`.
#[derive(Clone, Debug, PartialEq)]
pub struct NmfModel {
    /// Factorisation rank K.
    pub k: usize,
    /// β-divergence power (0 = IS/gamma, 1 = KL/Poisson, 2 = Gaussian).
    pub beta: f32,
    /// Tweedie dispersion φ.
    pub phi: f32,
    /// Exponential prior rate on W entries.
    pub lam_w: f32,
    /// Exponential prior rate on H entries.
    pub lam_h: f32,
    /// Apply the mirroring step (|·|) after each update (§3.2).
    pub mirror: bool,
}

impl NmfModel {
    /// Poisson-NMF (β = 1, φ = 1) with unit exponential priors — the
    /// configuration of Fig. 2(a), Fig. 3 and Fig. 5.
    pub fn poisson(k: usize) -> Self {
        NmfModel { k, beta: 1.0, phi: 1.0, lam_w: 1.0, lam_h: 1.0, mirror: true }
    }

    /// Compound-Poisson NMF (β = 0.5, φ = 1) — Fig. 2(b).
    pub fn compound_poisson(k: usize) -> Self {
        NmfModel { k, beta: 0.5, phi: 1.0, lam_w: 1.0, lam_h: 1.0, mirror: true }
    }

    /// Gaussian model (β = 2).
    pub fn gaussian(k: usize) -> Self {
        NmfModel { k, beta: 2.0, phi: 1.0, lam_w: 1.0, lam_h: 1.0, mirror: true }
    }

    /// Itakura-Saito model (β = 0).
    pub fn itakura_saito(k: usize) -> Self {
        NmfModel { k, beta: 0.0, phi: 1.0, lam_w: 1.0, lam_h: 1.0, mirror: true }
    }

    pub fn with_priors(mut self, lam_w: f32, lam_h: f32) -> Self {
        self.lam_w = lam_w;
        self.lam_h = lam_h;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Config("k must be positive".into()));
        }
        if self.phi <= 0.0 || self.lam_w <= 0.0 || self.lam_h <= 0.0 {
            return Err(Error::Config("phi/lam_w/lam_h must be positive".into()));
        }
        if self.beta > 1.0 && self.beta < 2.0 {
            // no Tweedie model exists for 1 < beta < 2 (p in (0,1));
            // the beta-divergence cost is still usable for MAP-style runs
            // but sampling synthetic data from it is undefined.
            crate::log_warn!(
                "warning: no Tweedie distribution exists for beta in (1,2); \
                 proceeding with the divergence only"
            );
        }
        Ok(())
    }

    /// Draw `(W, H)` from the exponential priors.
    pub fn sample_prior(&self, i: usize, j: usize, rng: &mut Rng) -> (Mat, Mat) {
        let w = Mat::exponential(i, self.k, self.lam_w as f64, rng);
        let h = Mat::exponential(self.k, j, self.lam_h as f64, rng);
        (w, h)
    }

    /// Unnormalised data log-likelihood over a dense matrix.
    pub fn loglik_dense(&self, w: &Mat, h: &Mat, v: &Mat) -> f64 {
        let mu = w.matmul_abs(h).expect("shape");
        let mut ll = 0.0f64;
        for (&vv, &m) in v.as_slice().iter().zip(mu.as_slice().iter()) {
            ll += tweedie::loglik_entry(vv, m + tweedie::MU_EPS, self.beta, self.phi) as f64;
        }
        ll
    }

    /// Log prior density (up to constants): `-lam Σ|w| - lam Σ|h|`.
    pub fn log_prior(&self, w: &Mat, h: &Mat) -> f64 {
        -(self.lam_w as f64) * w.abs_sum() - (self.lam_h as f64) * h.abs_sum()
    }

    /// Joint unnormalised log posterior.
    pub fn log_posterior_dense(&self, w: &Mat, h: &Mat, v: &Mat) -> f64 {
        self.loglik_dense(w, h, v) + self.log_prior(w, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        assert_eq!(NmfModel::poisson(8).beta, 1.0);
        assert_eq!(NmfModel::compound_poisson(8).beta, 0.5);
        assert_eq!(NmfModel::gaussian(8).beta, 2.0);
        assert_eq!(NmfModel::itakura_saito(8).beta, 0.0);
        assert!(NmfModel::poisson(8).validate().is_ok());
        assert!(NmfModel::poisson(0).validate().is_err());
        let mut bad = NmfModel::poisson(4);
        bad.phi = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn prior_sample_shapes_and_positivity() {
        let model = NmfModel::poisson(4);
        let mut rng = Rng::seed_from(1);
        let (w, h) = model.sample_prior(6, 9, &mut rng);
        assert_eq!(w.shape(), (6, 4));
        assert_eq!(h.shape(), (4, 9));
        assert!(w.as_slice().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn loglik_peaks_at_generative_factors() {
        let model = NmfModel::poisson(4);
        let mut rng = Rng::seed_from(2);
        let (w, h) = model.sample_prior(16, 16, &mut rng);
        let v = w.matmul_abs(&h).unwrap();
        let ll_true = model.loglik_dense(&w, &h, &v);
        let mut w2 = w.clone();
        for x in w2.as_mut_slice() {
            *x *= 2.0;
        }
        assert!(ll_true > model.loglik_dense(&w2, &h, &v));
    }

    #[test]
    fn log_posterior_includes_prior() {
        let model = NmfModel::poisson(2).with_priors(2.0, 3.0);
        let mut rng = Rng::seed_from(3);
        let (w, h) = model.sample_prior(4, 4, &mut rng);
        let v = w.matmul_abs(&h).unwrap();
        let lp = model.log_posterior_dense(&w, &h, &v);
        let expect = model.loglik_dense(&w, &h, &v)
            - 2.0 * w.abs_sum()
            - 3.0 * h.abs_sum();
        assert!((lp - expect).abs() < 1e-9);
    }
}
