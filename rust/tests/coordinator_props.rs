//! Property-based invariants of the coordination machinery (partition
//! laws, scheduler coverage, stacked-block permutation algebra, sparse
//! block decomposition) via the in-crate property harness.

use psgld::data::sparse::{BlockedSparse, Csr};
use psgld::linalg::{Mat, StackedBlocks};
use psgld::partition::{GridPartition, Part, PartSchedule, PartScheduler};
use psgld::rng::Rng;
use psgld::util::prop::{forall_explain, gen};

#[test]
fn prop_parts_tile_v_exactly() {
    // For any (rows, cols, B), the B cyclic parts partition [I]x[J].
    forall_explain(
        "cyclic-parts-tile",
        101,
        40,
        |rng| {
            let b = gen::int_in(rng, 1, 9);
            let rows = gen::int_in(rng, b, 40);
            let cols = gen::int_in(rng, b, 40);
            (rows, cols, b)
        },
        |&(rows, cols, b)| {
            let g = GridPartition::new(rows, cols, b).map_err(|e| e.to_string())?;
            let mut covered = vec![0u8; rows * cols];
            for p in 0..b {
                let part = Part::cyclic(b, p);
                for bi in 0..b {
                    for i in g.row_range(bi) {
                        for j in g.col_range(part.perm[bi]) {
                            covered[i * cols + j] += 1;
                        }
                    }
                }
            }
            if covered.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                Err("some entry not covered exactly once".into())
            }
        },
    );
}

#[test]
fn prop_part_sizes_sum_to_n() {
    forall_explain(
        "part-sizes-sum",
        102,
        40,
        |rng| {
            let b = gen::int_in(rng, 1, 8);
            (gen::int_in(rng, b, 50), gen::int_in(rng, b, 50), b)
        },
        |&(rows, cols, b)| {
            let g = GridPartition::new(rows, cols, b).map_err(|e| e.to_string())?;
            let total: usize = (0..b).map(|p| g.part_size(&Part::cyclic(b, p))).sum();
            (total == rows * cols)
                .then_some(())
                .ok_or_else(|| format!("{total} != {}", rows * cols))
        },
    );
}

#[test]
fn prop_scheduler_visits_every_entry_once_per_sweep() {
    // a B-iteration cyclic sweep touches every block exactly once
    forall_explain(
        "cyclic-sweep-coverage",
        103,
        30,
        |rng| gen::int_in(rng, 1, 12),
        |&b| {
            let mut sched = PartScheduler::new(PartSchedule::Cyclic, b);
            let mut rng = Rng::seed_from(0);
            let mut seen = vec![false; b * b];
            for _ in 0..b {
                let part = sched.next_part(&mut rng);
                for (bi, &bj) in part.perm.iter().enumerate() {
                    if seen[bi * b + bj] {
                        return Err(format!("block ({bi},{bj}) visited twice"));
                    }
                    seen[bi * b + bj] = true;
                }
            }
            seen.iter()
                .all(|&s| s)
                .then_some(())
                .ok_or_else(|| "unvisited block".into())
        },
    );
}

#[test]
fn prop_random_parts_always_valid() {
    forall_explain(
        "random-parts-valid",
        104,
        60,
        |rng| {
            let b = gen::int_in(rng, 1, 16);
            let mut sched = PartScheduler::new(PartSchedule::RandomPerm, b);
            sched.next_part(rng)
        },
        |part| part.is_valid().then_some(()).ok_or_else(|| "invalid perm".into()),
    );
}

#[test]
fn prop_gather_scatter_is_identity() {
    // scatter(perm, gather(perm, x)) == x for any permutation
    forall_explain(
        "gather-scatter-identity",
        105,
        40,
        |rng| {
            let b = gen::int_in(rng, 1, 8);
            let r = gen::int_in(rng, 1, 6);
            let c = gen::int_in(rng, 1, 6);
            let blocks: Vec<Mat> =
                (0..b).map(|_| Mat::uniform(r, c, -1.0, 1.0, rng)).collect();
            let stacked = StackedBlocks::from_blocks(&blocks).unwrap();
            let part = Part::random(b, rng);
            (stacked, part)
        },
        |(stacked, part)| {
            let [b, r, c] = stacked.dims();
            let mut gathered = StackedBlocks::zeros(b, r, c);
            stacked.gather_perm_into(&part.perm, &mut gathered);
            let mut back = StackedBlocks::zeros(b, r, c);
            back.scatter_perm_from(&part.perm, &gathered);
            (&back == stacked)
                .then_some(())
                .ok_or_else(|| "roundtrip mismatch".into())
        },
    );
}

#[test]
fn prop_blocked_sparse_preserves_entries_and_scale() {
    forall_explain(
        "blocked-sparse-conservation",
        106,
        30,
        |rng| {
            let rows = gen::int_in(rng, 4, 30);
            let cols = gen::int_in(rng, 4, 30);
            let b = gen::int_in(rng, 1, rows.min(cols).min(5));
            let nnz = gen::int_in(rng, 1, rows * cols / 2);
            let mut triplets = Vec::new();
            let mut seen = std::collections::HashSet::new();
            for _ in 0..nnz {
                let r = gen::int_in(rng, 0, rows - 1) as u32;
                let c = gen::int_in(rng, 0, cols - 1) as u32;
                if seen.insert((r, c)) {
                    triplets.push((r, c, gen::f32_in(rng, 0.5, 5.0)));
                }
            }
            (rows, cols, b, triplets)
        },
        |(rows, cols, b, triplets)| {
            let mut t = triplets.clone();
            let csr = Csr::from_triplets(*rows, *cols, &mut t).map_err(|e| e.to_string())?;
            let bs = BlockedSparse::from_csr(&csr, *b).map_err(|e| e.to_string())?;
            // entries conserved across blocks
            let total: usize = (0..*b)
                .flat_map(|bi| (0..*b).map(move |bj| (bi, bj)))
                .map(|(bi, bj)| bs.block(bi, bj).nnz())
                .sum();
            if total != csr.nnz() {
                return Err(format!("{total} != {}", csr.nnz()));
            }
            // part nnz sums to N over a sweep; scale is N/|part|
            let part_total: usize =
                (0..*b).map(|p| bs.part_nnz(&Part::cyclic(*b, p))).sum();
            if part_total != csr.nnz() {
                return Err(format!("parts {part_total} != {}", csr.nnz()));
            }
            for p in 0..*b {
                let part = Part::cyclic(*b, p);
                let pn = bs.part_nnz(&part);
                if pn > 0 {
                    let expect = csr.nnz() as f32 / pn as f32;
                    if (bs.scale(&part) - expect).abs() > 1e-5 {
                        return Err("scale mismatch".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stacked_roundtrip_row_and_col_stripes() {
    forall_explain(
        "stacked-stripe-roundtrip",
        107,
        30,
        |rng| {
            let b = gen::int_in(rng, 1, 6);
            let m = gen::int_in(rng, 1, 5);
            let k = gen::int_in(rng, 1, 5);
            let full = Mat::uniform(b * m, k, -2.0, 2.0, rng);
            (b, m, k, full)
        },
        |(b, m, k, full)| {
            let blocks: Vec<Mat> = (0..*b)
                .map(|bi| full.slice_block(bi * m, (bi + 1) * m, 0, *k))
                .collect();
            let stacked = StackedBlocks::from_blocks(&blocks).unwrap();
            (&stacked.to_row_stripes() == full)
                .then_some(())
                .ok_or_else(|| "row-stripe roundtrip failed".into())
        },
    );
}
