//! Integration contract for the sampler-health monitor (ISSUE 10):
//!
//! * the streaming estimators must agree with the batch diagnostics
//!   they shadow (`gelman_rubin`, `integrated_autocorr_time`) to 1e-9;
//! * a healthy async run must stay quiet — no non-finite, staleness or
//!   message-loss alerts from the default rule set;
//! * a fault-injected async run must raise at least one staleness /
//!   stall alert, and the health JSONL must round-trip through the
//!   crate's JSON parser;
//! * the OpenMetrics exposition must pass the lint, both rendered
//!   directly and scraped over HTTP from the metrics endpoint;
//! * the regression gate must accept an unchanged baseline and reject
//!   a degraded one.
//!
//! All tests share the process-global obs level and monitor state, so
//! they serialise on a local mutex and reset both registries on entry.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard, OnceLock};

use psgld::cluster::{
    psgld_distributed_async, ComputeModel, FaultPlan, NetworkModel, StragglerRule, TieBreak,
};
use psgld::config::{AsyncClusterConfig, RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::metrics::diagnostics::integrated_autocorr_time;
use psgld::metrics::gelman_rubin;
use psgld::model::NmfModel;
use psgld::monitor::{
    self, check_regression, lint_openmetrics, render_openmetrics, windowed_iat, AlertRule,
    MetricsServer, OnlineRhat, RingWindow,
};
use psgld::obs::{self, ObsLevel};
use psgld::rng::{Dist, Rng};
use psgld::util::Json;

fn serial() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Reset obs + monitor and pin the level so the monitor is live.
fn monitor_on() {
    obs::set_level_override(Some(ObsLevel::Counters));
    obs::reset();
    monitor::reset();
}

fn monitor_off() {
    monitor::reset();
    obs::reset();
    obs::set_level_override(None);
}

/// AR(1) chains — autocorrelated like a real sampler trace, so the
/// IAT is well above 1 and the R̂ comparison is not vacuous.
fn ar1_chain(seed: u64, n: usize, shift: f64) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed);
    let mut x = shift;
    (0..n)
        .map(|_| {
            x = 0.9 * x + rng.normal();
            x + shift
        })
        .collect()
}

/// Acceptance: the online split-R̂ agrees with the batch Gelman-Rubin
/// over the same draws to 1e-9.
#[test]
fn online_rhat_matches_batch_gelman_rubin() {
    let chains: Vec<Vec<f64>> =
        (0..3).map(|c| ar1_chain(100 + c, 500, c as f64 * 0.1)).collect();
    let mut online = OnlineRhat::new();
    for (c, chain) in chains.iter().enumerate() {
        for &x in chain {
            online.push(c, x);
        }
    }
    let batch = gelman_rubin(&chains);
    let stream = online.rhat().expect("3 equal-length chains of 500");
    assert!(
        (stream - batch).abs() < 1e-9,
        "online rhat {stream} != batch {batch}"
    );
}

/// Acceptance: the windowed IAT agrees with the batch estimator on the
/// same window to 1e-9 (it is the same Geyer sequence under the hood,
/// so the agreement is in fact exact).
#[test]
fn windowed_iat_matches_batch_estimator() {
    let values = ar1_chain(7, 300, 0.0);
    let mut win = RingWindow::new(512);
    for &x in &values {
        win.push(x);
    }
    let batch = integrated_autocorr_time(&values);
    let stream = windowed_iat(&win);
    assert!(
        (stream - batch).abs() < 1e-9,
        "windowed iat {stream} != batch {batch}"
    );
    assert!(batch > 1.5, "AR(0.9) chain should have IAT well above 1, got {batch}");
}

fn async_workload() -> (psgld::data::sparse::Csr, NmfModel, RunConfig) {
    let csr = movielens::movielens_like_dims(64, 80, 1600, 4, 21);
    let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
    let run = RunConfig::quick(40).with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
    (csr, model, run)
}

fn run_async(
    csr: &psgld::data::sparse::Csr,
    model: &NmfModel,
    run: &RunConfig,
    cfg: &AsyncClusterConfig,
    plan: &FaultPlan,
) {
    psgld_distributed_async(
        csr,
        model,
        4,
        run,
        4242,
        &NetworkModel::paper_cluster(),
        &ComputeModel::paper_node(),
        cfg,
        plan,
        TieBreak::Fifo,
        |_| 0.0,
    )
    .unwrap();
}

/// A fault-free async run must not trip the default rule set's
/// non-finite / staleness-pinned / message-loss alerts.
#[test]
fn healthy_async_run_is_quiet() {
    let _g = serial();
    monitor_on();
    let (csr, model, run) = async_workload();
    let cfg = AsyncClusterConfig::default().with_checkpoint_every(10);
    run_async(&csr, &model, &run, &cfg, &FaultPlan::empty());

    let noisy = ["non_finite_value", "staleness_pinned", "msgs_dropped_ratio"];
    for e in monitor::events() {
        assert!(
            !noisy.contains(&e.rule),
            "healthy run raised {}: {}",
            e.rule,
            e.message
        );
    }
    let snap = monitor::health_snapshot();
    assert!(!snap.nodes.is_empty(), "async run fed no node gauges");
    assert!(snap.nodes.iter().all(|n| n.execs > 0));
    monitor_off();
}

/// Acceptance: a fault-injected run (8x straggler under a tight
/// staleness bound) raises at least one staleness / stall alert, and
/// the health JSONL round-trips through the crate JSON parser.
#[test]
fn faulty_async_run_raises_staleness_or_stall_alert() {
    let _g = serial();
    monitor_on();
    // tighten the node rules: the smoke workload is small, so the
    // defaults' min-exec floors would mask the injected fault
    monitor::set_rules(vec![
        AlertRule::StallTimeRatioAbove { ratio: 0.5, min_execs: 8, cooldown: 50 },
        AlertRule::StalenessPinned { k: 4, cooldown: 50 },
    ]);
    let (csr, model, run) = async_workload();
    let cfg = AsyncClusterConfig::default().with_tau(1).with_checkpoint_every(10);
    let plan = FaultPlan {
        stragglers: vec![StragglerRule { node: 0, from_t: 1, to_t: 30, factor: 8.0 }],
        ..FaultPlan::empty()
    };
    run_async(&csr, &model, &run, &cfg, &plan);

    let events = monitor::events();
    assert!(
        events
            .iter()
            .any(|e| e.rule == "staleness_pinned" || e.rule == "stall_time_ratio_above"),
        "straggler run raised no staleness/stall alert; events: {:?}",
        events.iter().map(|e| e.rule).collect::<Vec<_>>()
    );

    let dir = std::env::temp_dir().join("psgld_monitor_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("health.jsonl");
    let n = monitor::write_health_jsonl(&path).unwrap();
    assert_eq!(n, events.len());
    let body = std::fs::read_to_string(&path).unwrap();
    for line in body.lines() {
        let j = Json::parse(line).unwrap();
        assert!(j.field("rule").is_ok(), "health line missing rule: {line}");
        assert_eq!(j.field("schema").unwrap().as_str().unwrap(), "psgld-health/1");
    }
    monitor_off();
}

/// The rendered exposition passes the OpenMetrics lint and carries the
/// chain gauges the monitor was fed.
#[test]
fn exposition_renders_and_lints() {
    let _g = serial();
    monitor_on();
    let mut rng = Rng::seed_from(9);
    for t in 1..=50u64 {
        monitor::observe_sample(t, t as f64 * 1e-3, rng.normal());
    }
    let text = render_openmetrics();
    lint_openmetrics(&text).unwrap_or_else(|e| panic!("lint failed: {e}\n{text}"));
    assert!(text.contains("pallas_health_samples_total{chain=\"0\"} 50"), "{text}");
    assert!(text.ends_with("# EOF\n"));
    monitor_off();
}

/// End-to-end scrape: the endpoint serves a lint-clean exposition with
/// the OpenMetrics content type over plain HTTP/1.1.
#[test]
fn metrics_endpoint_serves_lint_clean_exposition() {
    let _g = serial();
    monitor_on();
    monitor::with_chain(1, || {
        for t in 1..=20u64 {
            monitor::observe_sample(t, t as f64 * 1e-3, 1.0 + t as f64);
        }
    });
    let server = MetricsServer::spawn("127.0.0.1:0").unwrap();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    drop(server);

    assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
    assert!(resp.contains("application/openmetrics-text"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or_else(|| panic!("no body: {resp}"));
    lint_openmetrics(body).unwrap_or_else(|e| panic!("scraped body fails lint: {e}\n{body}"));
    assert!(body.contains("pallas_health_samples_total{chain=\"1\"} 20"), "{body}");
    monitor_off();
}

/// The regression gate accepts an identical baseline and rejects a
/// synthetically degraded current run.
#[test]
fn regression_gate_rejects_degraded_bench() {
    let dir = std::env::temp_dir().join("psgld_monitor_itest_gate");
    let base = dir.join("base");
    let cur = dir.join("cur");
    std::fs::create_dir_all(&base).unwrap();
    std::fs::create_dir_all(&cur).unwrap();
    let rows = |scale: f64| {
        format!(
            "[\n  {{\"name\":\"fig5/psgld_step\",\"ns_per_iter\":100.0,\
             \"ops_per_s\":{:.2},\"unit\":\"grad-entries\",\"threads\":2}}\n]\n",
            1e7 * scale
        )
    };
    std::fs::write(base.join("BENCH_fig5.json"), rows(1.0)).unwrap();

    std::fs::write(cur.join("BENCH_fig5.json"), rows(1.0)).unwrap();
    let report = check_regression(&base, &cur, 0.2).unwrap();
    assert!(report.passed(), "identical bench flagged: {:?}", report.regressions);
    assert_eq!(report.compared, 1);

    std::fs::write(cur.join("BENCH_fig5.json"), rows(0.1)).unwrap();
    let report = check_regression(&base, &cur, 0.5).unwrap();
    assert!(!report.passed(), "10x degradation not flagged");
    assert_eq!(report.regressions.len(), 1);
    assert_eq!(report.regressions[0].key, "fig5/psgld_step:ops_per_s");
}
