//! Integration contract for the observability layer (ISSUE 9):
//!
//! * at `PALLAS_OBS=full`, the per-phase totals must cover the step
//!   span's wall time to within 5% — the taxonomy is exhaustive on the
//!   hot path, not decorative;
//! * the async simulator's virtual-time slices must reconcile exactly
//!   with its report counters (compute ↔ busy, stall ↔ stall);
//! * exported traces must pass the schema validator that the CLI's
//!   `validate-trace` subcommand runs;
//! * instrumentation must never perturb the chain (bitwise identical
//!   at off vs full) and its overhead must stay bounded.
//!
//! All tests share one process-global obs level, so they serialise on
//! a local mutex and `reset()` the metrics registry on entry.

use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use psgld::cluster::{
    psgld_distributed_async, ComputeModel, FaultPlan, NetworkModel, StragglerRule, TieBreak,
};
use psgld::config::{AsyncClusterConfig, RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::model::NmfModel;
use psgld::obs::{self, Counter, ObsLevel, Phase, Span};
use psgld::samplers::{ExecMode, Psgld, Sampler};
use psgld::util::Json;

fn serial() -> MutexGuard<'static, ()> {
    static M: OnceLock<Mutex<()>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn fig5_like_sampler(seed: u64) -> Psgld {
    let csr = movielens::movielens_like_dims(180, 220, 18_000, 32, 7);
    let model = NmfModel::poisson(32).with_priors(2.0, 2.0);
    let run = RunConfig::quick(1_000).with_step(StepSchedule::Polynomial { a: 1e-3, b: 0.51 });
    Psgld::new_sparse(&csr, &model, 6, run, seed)
        .unwrap()
        .with_exec_mode(ExecMode::Inline)
}

/// Acceptance: with obs on, schedule + kernel + noise account for the
/// step span's wall time to within 5% (single-threaded so the phases
/// nest inside the step interval with no concurrency double-count).
#[test]
fn phase_totals_cover_step_wall_time() {
    let _g = serial();
    obs::set_level_override(Some(ObsLevel::Full));
    obs::reset();
    obs::clear_events();

    let steps = 40u64;
    let mut p = fig5_like_sampler(11);
    for t in 1..=steps {
        p.step(t);
    }

    let s = obs::snapshot();
    assert_eq!(s.counter(Counter::Steps), steps);
    assert_eq!(s.phase_count[Phase::Step.idx()], steps);
    let step_s = s.phase_seconds(Phase::Step);
    let covered = s.phase_seconds(Phase::Schedule)
        + s.phase_seconds(Phase::Kernel)
        + s.phase_seconds(Phase::Noise);
    assert!(step_s > 0.0);
    let frac = covered / step_s;
    assert!(
        frac > 0.95 && frac <= 1.02,
        "phase taxonomy leaks wall time: schedule+kernel+noise = {covered:.6}s \
         vs step = {step_s:.6}s (coverage {frac:.3})"
    );

    // the exported artifacts round-trip through the schema validator
    let dir = std::env::temp_dir().join("psgld_obs_itest");
    let trace_path = dir.join("trace.json");
    let summary_path = dir.join("summary.json");
    obs::write_chrome_trace(&trace_path, &[]).unwrap();
    obs::write_summary(&summary_path).unwrap();
    let trace = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    obs::validate_trace(&trace).unwrap();
    let summary = Json::parse(&std::fs::read_to_string(&summary_path).unwrap()).unwrap();
    assert_eq!(
        summary.field("counters").unwrap().field("steps").unwrap().as_u64().unwrap(),
        steps
    );
    let kernel = summary.field("phases").unwrap().field("kernel").unwrap();
    assert!(kernel.field("total_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(kernel.field("p99_ns").unwrap().as_f64().unwrap() > 0.0);

    let _ = std::fs::remove_dir_all(&dir);
    obs::reset();
    obs::set_level_override(None);
}

/// The async simulator's virtual-time slices reconcile exactly with
/// its aggregate report: compute slices sum to `busy_seconds`, stall
/// slices to `stall_seconds`, and the merged trace validates.
#[test]
fn async_vt_events_match_report() {
    let _g = serial();
    obs::set_level_override(Some(ObsLevel::Full));
    obs::reset();
    obs::clear_events();

    let b = 4usize;
    let csr = movielens::movielens_like_dims(64, 80, 1600, 4, 21);
    let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
    let run = RunConfig::quick(40).with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
    // one 8x straggler + tau=0 forces the other nodes to stall on its
    // ring hand-offs, so every slice kind we assert on actually occurs
    let plan = FaultPlan {
        stragglers: vec![StragglerRule { node: 0, from_t: 1, to_t: 30, factor: 8.0 }],
        ..FaultPlan::empty()
    };
    let cfg = AsyncClusterConfig::default().with_checkpoint_every(10);
    let rep = psgld_distributed_async(
        &csr,
        &model,
        b,
        &run,
        4242,
        &NetworkModel::paper_cluster(),
        &ComputeModel::paper_node(),
        &cfg,
        &plan,
        TieBreak::Fifo,
        |_| 0.0,
    )
    .unwrap();

    assert!(rep.stall_seconds > 0.0, "straggler plan produced no stalls");
    assert!(!rep.vt_events.is_empty());
    let sum_for = |cat: &str| -> f64 {
        rep.vt_events.iter().filter(|e| e.cat == cat).map(|e| e.dur_s).sum()
    };
    let compute: f64 = sum_for("kernel");
    let stall: f64 = sum_for("stall");
    let tol = |x: f64| 1e-9 * x.max(1.0);
    assert!(
        (compute - rep.busy_seconds).abs() < tol(rep.busy_seconds),
        "compute slices {compute} != busy_seconds {}",
        rep.busy_seconds
    );
    assert!(
        (stall - rep.stall_seconds).abs() < tol(rep.stall_seconds),
        "stall slices {stall} != stall_seconds {}",
        rep.stall_seconds
    );
    assert!(
        rep.vt_events.iter().any(|e| e.cat == "checkpoint"),
        "checkpoint slices missing"
    );
    // counters agree with the report
    let s = obs::snapshot();
    assert!(s.counter(Counter::Stalls) > 0);
    assert_eq!(s.counter(Counter::MsgsSent), rep.messages_sent);
    assert_eq!(s.counter(Counter::Checkpoints), rep.checkpoints_taken);

    // the merged wall + virtual-time trace passes the CLI validator
    let dir = std::env::temp_dir().join("psgld_obs_itest_async");
    let path = dir.join("trace.json");
    obs::write_chrome_trace(&path, &rep.vt_events).unwrap();
    let trace = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    obs::validate_trace(&trace).unwrap();
    // virtual-time slices land on their own process with per-node tracks
    let events = trace.field("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.iter().any(|e| {
        e.field_opt("ph").and_then(|p| p.as_str().ok()) == Some("X")
            && e.field_opt("pid").and_then(|p| p.as_usize().ok()) == Some(1)
            && e.field_opt("cat").and_then(|c| c.as_str().ok()) == Some("stall")
    }));

    let _ = std::fs::remove_dir_all(&dir);
    obs::reset();
    obs::set_level_override(None);
}

/// Instrumentation must not perturb the chain: the sampled factors are
/// bitwise identical with obs off and obs full.
#[test]
fn obs_level_never_touches_the_chain() {
    let _g = serial();
    let steps = 20u64;

    obs::set_level_override(Some(ObsLevel::Off));
    let mut off = fig5_like_sampler(33);
    for t in 1..=steps {
        off.step(t);
    }

    obs::set_level_override(Some(ObsLevel::Full));
    obs::clear_events();
    let mut full = fig5_like_sampler(33);
    for t in 1..=steps {
        full.step(t);
    }
    obs::clear_events();
    obs::reset();
    obs::set_level_override(None);

    assert_eq!(off.state().w, full.state().w, "obs=full changed the W chain");
    assert_eq!(off.state().ht, full.state().ht, "obs=full changed the H chain");
}

/// With obs off a span is a relaxed load and a branch: no clock read,
/// no allocation. 200 ns/span is ~100x the expected cost — the bound
/// only exists to catch an accidental always-on clock or lock.
#[test]
fn span_overhead_off_is_negligible() {
    let _g = serial();
    obs::set_level_override(Some(ObsLevel::Off));
    for _ in 0..10_000 {
        let _s = Span::enter(Phase::Kernel, "overhead_probe");
    }
    let iters = 2_000_000u64;
    let tick = Instant::now();
    for _ in 0..iters {
        let _s = Span::enter(Phase::Kernel, "overhead_probe");
        std::hint::black_box(&_s);
    }
    let ns_per = tick.elapsed().as_nanos() as f64 / iters as f64;
    obs::set_level_override(None);
    assert!(ns_per < 200.0, "obs-off span costs {ns_per:.1} ns/call");
}

/// Full instrumentation on real sampler steps stays within 3x of the
/// uninstrumented path (measured: a few percent; the bound is slack
/// for noisy CI boxes).
#[test]
fn full_overhead_is_bounded_on_real_steps() {
    let _g = serial();
    let steps = 20u64;

    obs::set_level_override(Some(ObsLevel::Off));
    let mut p = fig5_like_sampler(55);
    for t in 1..=5 {
        p.step(t);
    }
    let tick = Instant::now();
    for t in 6..=5 + steps {
        p.step(t);
    }
    let off_s = tick.elapsed().as_secs_f64();

    obs::set_level_override(Some(ObsLevel::Full));
    obs::clear_events();
    let mut p = fig5_like_sampler(55);
    for t in 1..=5 {
        p.step(t);
    }
    let tick = Instant::now();
    for t in 6..=5 + steps {
        p.step(t);
    }
    let full_s = tick.elapsed().as_secs_f64();
    obs::clear_events();
    obs::reset();
    obs::set_level_override(None);

    let ratio = full_s / off_s.max(1e-12);
    assert!(
        ratio < 3.0,
        "obs=full is {ratio:.2}x the uninstrumented step ({full_s:.6}s vs {off_s:.6}s)"
    );
}
