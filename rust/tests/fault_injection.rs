//! Property tests for the fault-injecting async cluster executor:
//!
//! 1. `tau = 0` + empty `FaultPlan` is **bitwise identical** to the
//!    synchronous simulator, across worker counts.
//! 2. A seeded `FaultPlan` replayed twice yields identical traces and
//!    final state, event-for-event.
//! 3. A crash at every checkpoint boundary recovers to the exact
//!    pre-crash chain state (in-memory and on-disk checkpoints).
//! 4. Recorded staleness never exceeds `tau` (enforced by the ledger,
//!    re-asserted here from the outside).
//! 5. Permuting event-queue tie-breaking never touches the chain: the
//!    per-block RNG streams are keyed by `(seed, t, block)`, not by pop
//!    order.

use std::path::PathBuf;

use psgld::cluster::{
    psgld_distributed_async, psgld_distributed_full, AsyncSimReport, ComputeModel, CrashRule,
    FaultPlan, FaultRates, NetworkModel, StragglerRule, TieBreak,
};
use psgld::config::{AsyncClusterConfig, RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::data::sparse::Csr;
use psgld::model::NmfModel;

const SEED: u64 = 2015;
const T_TOTAL: u64 = 40;

fn workload() -> (Csr, NmfModel, RunConfig) {
    let csr = movielens::movielens_like_dims(48, 60, 900, 3, 13);
    // mirror (Poisson) model: the async executor's nonneg fast path and
    // the sync simulator's nonneg_hint agree unconditionally for mirror
    // models, which the bitwise contract relies on.
    let model = NmfModel::poisson(3).with_priors(2.0, 2.0);
    let run = RunConfig::quick(T_TOTAL)
        .with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 })
        .with_monitor_every(5);
    (csr, model, run)
}

fn run_async(
    b: usize,
    cfg: &AsyncClusterConfig,
    plan: &FaultPlan,
    tie: TieBreak,
) -> AsyncSimReport {
    let (csr, model, run) = workload();
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    psgld_distributed_async(
        &csr, &model, b, &run, SEED, &net, &compute, cfg, plan, tie,
        |s| f64::from(s.w.as_slice().iter().sum::<f32>()),
    )
    .expect("async run")
}

fn assert_same_chain(a: &AsyncSimReport, b: &AsyncSimReport) {
    assert_eq!(a.state.w, b.state.w, "W diverged");
    assert_eq!(a.state.ht, b.state.ht, "H diverged");
    assert_eq!(a.trace.iters, b.trace.iters, "trace iterations diverged");
    assert_eq!(a.trace.values, b.trace.values, "trace values diverged");
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("psgld_fault_tests").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------- (1)

#[test]
fn tau_zero_no_faults_is_bitwise_identical_to_sync_simulator() {
    let (csr, model, run) = workload();
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    for b in [2usize, 3, 4] {
        let sync = psgld_distributed_full(&csr, &model, b, &run, SEED, &net, &compute, |s| {
            f64::from(s.w.as_slice().iter().sum::<f32>())
        })
        .expect("sync run");
        let sync_state = sync.state.expect("full fidelity keeps state");
        let sync_trace = sync.trace.expect("full fidelity keeps trace");

        let rep = run_async(b, &AsyncClusterConfig::default(), &FaultPlan::empty(), TieBreak::Fifo);
        assert_eq!(rep.state.w, sync_state.w, "B={b}: async W != sync W");
        assert_eq!(rep.state.ht, sync_state.ht, "B={b}: async H != sync H");
        assert_eq!(rep.trace.iters, sync_trace.iters, "B={b}: monitor points differ");
        assert_eq!(rep.trace.values, sync_trace.values, "B={b}: monitor values differ");
        assert_eq!(rep.iterations, T_TOTAL);
        assert_eq!(rep.executed_iterations, T_TOTAL * b as u64, "no re-execution expected");
        assert_eq!(rep.recoveries, 0);
        assert_eq!(rep.ledger.max_staleness(), 0, "tau=0 must admit no staleness");
    }
}

// ---------------------------------------------------------------- (2)

#[test]
fn seeded_fault_plan_replays_to_identical_traces() {
    let b = 4;
    let rates = FaultRates {
        straggler_prob: 0.05,
        crash_prob: 0.02,
        drop_prob: 0.05,
        delay_prob: 0.05,
        ..Default::default()
    };
    let plan = FaultPlan::seeded(77, b, T_TOTAL, &rates);
    assert!(!plan.is_empty(), "rates high enough to generate faults");
    let cfg = AsyncClusterConfig::default().with_tau(4).with_checkpoint_every(8);

    let a = run_async(b, &cfg, &plan, TieBreak::Fifo);
    let c = run_async(b, &cfg, &plan, TieBreak::Fifo);
    assert_same_chain(&a, &c);
    // the whole run replays, not just the chain: virtual time, event
    // counters and the staleness ledger are identical too
    assert_eq!(a.trace.seconds, c.trace.seconds, "virtual-time trace diverged");
    assert_eq!(a.virtual_seconds, c.virtual_seconds);
    assert_eq!(a.executed_iterations, c.executed_iterations);
    assert_eq!(a.recoveries, c.recoveries);
    assert_eq!(a.messages_dropped, c.messages_dropped);
    assert_eq!(a.retries, c.retries);
    assert_eq!(a.ledger.records(), c.ledger.records());
    assert_eq!(a.trace.node_stats, c.trace.node_stats);
}

// ---------------------------------------------------------------- (3)

#[test]
fn crash_at_every_checkpoint_boundary_recovers_exact_state() {
    let b = 4;
    let every = 8u64;
    let baseline = run_async(
        b,
        &AsyncClusterConfig::default().with_checkpoint_every(every),
        &FaultPlan::empty(),
        TieBreak::Fifo,
    );

    // one crash right after each checkpoint boundary (t = c + 1), plus
    // one before any checkpoint exists (rolls back to the init state)
    let crashes: Vec<CrashRule> = (0..T_TOTAL / every)
        .map(|i| CrashRule { node: (i as usize) % b, at_t: i * every + 1 })
        .collect();
    let plan = FaultPlan { crashes, ..Default::default() };
    let cfg = AsyncClusterConfig::default().with_checkpoint_every(every);
    let rep = run_async(b, &cfg, &plan, TieBreak::Fifo);
    assert_eq!(rep.recoveries, (T_TOTAL / every), "every crash rule must fire once");
    assert!(
        rep.executed_iterations >= T_TOTAL * b as u64,
        "rollback must never lose delivered iterations"
    );
    // at tau = 0 the replay after rollback is bitwise, so the final
    // chain equals the crash-free run exactly
    assert_same_chain(&baseline, &rep);

    // same contract when recovery goes through a checkpoint on disk
    let dir = tmp("boundary_crashes");
    let cfg_disk = AsyncClusterConfig::default()
        .with_checkpoint_every(every)
        .with_checkpoint_dir(dir.to_str().unwrap());
    let rep_disk = run_async(b, &cfg_disk, &plan, TieBreak::Fifo);
    assert_same_chain(&baseline, &rep_disk);
    assert!(rep_disk.checkpoints_taken >= T_TOTAL / every);
}

// ---------------------------------------------------------------- (4)

#[test]
fn staleness_never_exceeds_tau() {
    let b = 4;
    // Staleness is content lineage and accumulates: against a permanent
    // straggler a fast node consumes its init copy at staleness 1 on
    // the first lap, its own lap-old copy at staleness B = 4 on the
    // second, and would hit 2B - 1 = 7 > tau on the third — so with
    // tau = B the stale path is exercised (max > 0) AND the bound bites
    // (stalls > 0) in the same run.
    let tau = b as u64;
    let plan = FaultPlan {
        stragglers: vec![StragglerRule { node: 0, from_t: 1, to_t: T_TOTAL, factor: 50.0 }],
        ..Default::default()
    };
    let cfg = AsyncClusterConfig::default().with_tau(tau);
    let rep = run_async(b, &cfg, &plan, TieBreak::Fifo);
    let max = rep.ledger.max_staleness();
    assert!(max <= tau, "ledger recorded staleness {max} > tau {tau}");
    assert!(max > 0, "a 50x straggler must force the fast nodes onto stale blocks");
    assert!(
        rep.trace.node_stats.iter().any(|n| n.stalls > 0),
        "the bound must also bite: someone has to stall at tau"
    );
    for n in &rep.trace.node_stats {
        assert!(n.max_staleness <= tau, "node {} exceeded tau", n.node);
    }
    assert_eq!(rep.iterations, T_TOTAL, "bounded staleness still completes the run");
}

// ---------------------------------------------------------------- (5)

#[test]
fn event_tie_breaking_cannot_touch_the_chain() {
    let b = 4;
    let rates = FaultRates {
        straggler_prob: 0.1,
        delay_prob: 0.1,
        crash_prob: 0.0,
        drop_prob: 0.0,
        ..Default::default()
    };
    let plan = FaultPlan::seeded(31, b, T_TOTAL, &rates);
    let cfg = AsyncClusterConfig::default().with_tau(b as u64).with_checkpoint_every(8);

    let reference = run_async(b, &cfg, &plan, TieBreak::Fifo);
    for tie in [TieBreak::Lifo, TieBreak::NodeDesc, TieBreak::Hashed(1), TieBreak::Hashed(2)] {
        let rep = run_async(b, &cfg, &plan, tie);
        assert_same_chain(&reference, &rep);
        assert_eq!(
            reference.ledger.records(),
            rep.ledger.records(),
            "{tie:?}: staleness observations must be pop-order invariant"
        );
    }
}
