//! Integration tests over the REAL artifacts (`make artifacts` first):
//! the lowered HLO executables must agree numerically with the native
//! Rust kernels — this is the contract that lets the coordinator treat
//! the two backends interchangeably.

use std::path::{Path, PathBuf};

use psgld::kernels::{dense_block_grads, sign0};
use psgld::linalg::{Mat, StackedBlocks};
use psgld::model::NmfModel;
use psgld::rng::Rng;
use psgld::runtime::{ArtifactKind, XlaRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

fn stacked_uniform(rng: &mut Rng, b: usize, r: usize, c: usize, lo: f32, hi: f32) -> StackedBlocks {
    let blocks: Vec<Mat> = (0..b).map(|_| Mat::uniform(r, c, lo, hi, rng)).collect();
    StackedBlocks::from_blocks(&blocks).unwrap()
}

#[test]
fn loglik_hlo_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_full(ArtifactKind::Loglik, 1.0, 128, 128, 16)
        .unwrap()
        .name
        .clone();
    let mut rng = Rng::seed_from(1);
    let w = Mat::uniform(128, 16, 0.1, 1.0, &mut rng);
    let h = Mat::uniform(16, 128, 0.1, 1.0, &mut rng);
    let v = Mat::from_fn(128, 128, |i, j| ((i * 31 + j * 7) % 6) as f32);

    let hlo = rt
        .loglik(&entry, w.as_slice(), h.as_slice(), v.as_slice(), (128, 128, 16))
        .unwrap();
    let model = NmfModel::poisson(16);
    let native = model.loglik_dense(&w, &h, &v);
    let rel = (hlo - native).abs() / native.abs().max(1.0);
    assert!(rel < 1e-4, "hlo {hlo} vs native {native} (rel {rel})");
}

#[test]
fn part_update_drift_matches_native_gradients() {
    // Same seed => identical threefry noise; subtracting a (scale=0,
    // lam=0) call isolates the deterministic drift, which must equal
    // eps * (scale * G - lam * sign) from the native kernel.
    // Uses the no-mirror ablation artifact (beta=2, B=4, 32x32, K=16)
    // so the subtraction is exact.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_part_update(2.0, 4, 32, 32, 16, false)
        .unwrap()
        .name
        .clone();

    let mut rng = Rng::seed_from(2);
    let ws = stacked_uniform(&mut rng, 4, 32, 16, 0.1, 1.0);
    let hs = stacked_uniform(&mut rng, 4, 16, 32, 0.1, 1.0);
    let vs = stacked_uniform(&mut rng, 4, 32, 32, 0.0, 5.0);

    let (eps, scale, lam) = (0.01f32, 3.0f32, 0.7f32);
    let seed = [11u32, 22u32];
    let (w_full, h_full) = rt
        .part_update(&entry, &ws, &hs, &vs, eps, scale, lam, lam, seed)
        .unwrap();
    let (w_noise, h_noise) = rt
        .part_update(&entry, &ws, &hs, &vs, eps, 0.0, 0.0, 0.0, seed)
        .unwrap();

    for b in 0..4 {
        let w_b = Mat::from_vec(32, 16, ws.block(b).to_vec()).unwrap();
        let h_b = Mat::from_vec(16, 32, hs.block(b).to_vec()).unwrap();
        let v_b = Mat::from_vec(32, 32, vs.block(b).to_vec()).unwrap();
        let g = dense_block_grads(&w_b, &h_b.transpose(), &v_b, 2.0, 1.0);

        // W drift
        for idx in 0..32 * 16 {
            let drift = w_full.block(b)[idx] - w_noise.block(b)[idx];
            let expect = eps
                * (scale * g.gw.as_slice()[idx] - lam * sign0(w_b.as_slice()[idx]));
            assert!(
                (drift - expect).abs() < 2e-3 * expect.abs().max(1.0),
                "block {b} w[{idx}]: {drift} vs {expect}"
            );
        }
        // H drift (HLO returns K x n; native ght is n x K)
        let ght = g.ght.transpose(); // K x n
        for idx in 0..16 * 32 {
            let drift = h_full.block(b)[idx] - h_noise.block(b)[idx];
            let expect = eps
                * (scale * ght.as_slice()[idx] - lam * sign0(h_b.as_slice()[idx]));
            assert!(
                (drift - expect).abs() < 2e-3 * expect.abs().max(1.0),
                "block {b} h[{idx}]: {drift} vs {expect}"
            );
        }
    }
}

#[test]
fn part_update_noise_is_2eps_gaussian() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_part_update(2.0, 4, 32, 32, 16, false)
        .unwrap()
        .name
        .clone();
    let mut rng = Rng::seed_from(3);
    let ws = stacked_uniform(&mut rng, 4, 32, 16, 0.4, 0.6);
    let hs = stacked_uniform(&mut rng, 4, 16, 32, 0.4, 0.6);
    let vs = stacked_uniform(&mut rng, 4, 32, 32, 0.0, 3.0);
    let eps = 0.04f32;

    let mut all = Vec::new();
    for s in 0..40u32 {
        let (w2, _) = rt
            .part_update(&entry, &ws, &hs, &vs, eps, 0.0, 0.0, 0.0, [s, 77])
            .unwrap();
        for b in 0..4 {
            for idx in 0..32 * 16 {
                all.push((w2.block(b)[idx] - ws.block(b)[idx]) as f64);
            }
        }
    }
    let n = all.len() as f64;
    let mean = all.iter().sum::<f64>() / n;
    let var = all.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    assert!(mean.abs() < 0.003, "noise mean {mean}");
    assert!(
        (var - 2.0 * eps as f64).abs() < 0.004,
        "noise var {var} vs {}",
        2.0 * eps
    );
    // different seeds give different noise
    let (a, _) = rt
        .part_update(&entry, &ws, &hs, &vs, eps, 0.0, 0.0, 0.0, [1, 1])
        .unwrap();
    let (b2, _) = rt
        .part_update(&entry, &ws, &hs, &vs, eps, 0.0, 0.0, 0.0, [1, 2])
        .unwrap();
    assert_ne!(a.block(0)[..8], b2.block(0)[..8]);
}

#[test]
fn mirrored_part_update_keeps_state_nonnegative() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_part_update(1.0, 4, 32, 32, 16, true)
        .unwrap()
        .name
        .clone();
    let mut rng = Rng::seed_from(4);
    let ws = stacked_uniform(&mut rng, 4, 32, 16, 0.0, 0.3);
    let hs = stacked_uniform(&mut rng, 4, 16, 32, 0.0, 0.3);
    let vs = stacked_uniform(&mut rng, 4, 32, 32, 0.0, 3.0);
    // huge eps so noise definitely crosses zero pre-mirroring
    let (w2, h2) = rt
        .part_update(&entry, &ws, &hs, &vs, 0.5, 1.0, 1.0, 1.0, [5, 6])
        .unwrap();
    assert!(w2.as_slice().iter().all(|&x| x >= 0.0));
    assert!(h2.as_slice().iter().all(|&x| x >= 0.0));
}

#[test]
fn ld_update_roundtrip_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_full(ArtifactKind::LdUpdate, 1.0, 128, 128, 16)
        .unwrap()
        .name
        .clone();
    let mut rng = Rng::seed_from(5);
    let w = Mat::uniform(128, 16, 0.1, 1.0, &mut rng);
    let h = Mat::uniform(16, 128, 0.1, 1.0, &mut rng);
    let v = Mat::from_fn(128, 128, |i, j| ((i + j) % 4) as f32);
    let (w2, h2) = rt
        .ld_update(
            &entry,
            w.as_slice(),
            h.as_slice(),
            v.as_slice(),
            (128, 128, 16),
            1e-3,
            1.0,
            1.0,
            [9, 9],
        )
        .unwrap();
    assert_eq!(w2.len(), 128 * 16);
    assert_eq!(h2.len(), 16 * 128);
    assert!(w2.iter().all(|x| x.is_finite() && *x >= 0.0));
    assert!(h2.iter().all(|x| x.is_finite() && *x >= 0.0));
    // the update actually moved the state
    let moved = w2
        .iter()
        .zip(w.as_slice())
        .filter(|(a, b)| (*a - *b).abs() > 1e-6)
        .count();
    assert!(moved > 100, "only {moved} entries moved");
}
