//! Equivalence pins for the §Perf sparse/SIMD work:
//!
//! * the block-local CSR kernel agrees with the pre-PR local-index COO
//!   reference walk (`grads_sparse_coo_ref`) on every block,
//! * the scalar and AVX2+FMA tiers are **bitwise** identical — they
//!   share one canonical arithmetic order (8-lane split accumulators,
//!   fixed reduction tree, `mul_add` tails), so switching tiers can
//!   never change a chain,
//! * full sparse PSGLD chains are bitwise identical across
//!   {scalar, SIMD} x {1, 2, default} workers,
//! * the batched Langevin noise slab consumes the RNG stream exactly
//!   like a per-element draw.

use std::ops::Range;
use std::sync::Mutex;

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::data::sparse::BlockedSparse;
use psgld::kernels::{
    avx2_available, grads_dense_tiled, grads_sparse_coo_ref, grads_sparse_core, nonneg_hint,
    set_tier_override, sgld_apply_core, sign0, SimdTier,
};
use psgld::linalg::Mat;
use psgld::model::NmfModel;
use psgld::rng::{normal_ziggurat, Rng};
use psgld::samplers::{ExecMode, Psgld, Sampler};
use psgld::util::parallel::{default_threads, ScratchArena};

/// The SIMD tier override is process-global; tests that touch it hold
/// this lock and restore the auto-detected tier on drop.
static TIER_LOCK: Mutex<()> = Mutex::new(());

struct TierGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl<'a> TierGuard<'a> {
    fn acquire() -> Self {
        TierGuard(TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for TierGuard<'_> {
    fn drop(&mut self) {
        set_tier_override(None);
    }
}

const KS: [usize; 4] = [1, 3, 8, 17];

fn mixed_sign_factors(m: usize, n: usize, k: usize, rng: &mut Rng) -> (Mat, Mat) {
    (
        Mat::uniform(m, k, -1.0, 1.0, rng),
        Mat::uniform(n, k, -1.0, 1.0, rng),
    )
}

fn positive_factors(m: usize, n: usize, k: usize, rng: &mut Rng) -> (Mat, Mat) {
    (
        Mat::uniform(m, k, 0.05, 1.0, rng),
        Mat::uniform(n, k, 0.05, 1.0, rng),
    )
}

fn block_dims(bs: &BlockedSparse, bi: usize, bj: usize) -> (Range<usize>, Range<usize>) {
    (bs.grid().row_range(bi), bs.grid().col_range(bj))
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = 1e-4 * x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

/// The CSR kernel reproduces the pre-PR COO walk on every block of the
/// grid, for both the nonneg fast path and the generic signed path, at
/// awkward K values (lane tails of 1, 3, 0, 1 against the 8-lane body).
#[test]
fn csr_kernel_matches_coo_reference_walk() {
    let _g = TierGuard::acquire();
    set_tier_override(Some(SimdTier::Scalar));
    let csr = movielens::movielens_like_dims(37, 41, 700, 4, 11);
    let bs = BlockedSparse::from_csr(&csr, 3).unwrap();
    let mut rng = Rng::seed_from(42);
    for &k in &KS {
        for nonneg in [false, true] {
            for bi in 0..3 {
                for bj in 0..3 {
                    let blk = bs.block(bi, bj);
                    let (rr, cr) = block_dims(&bs, bi, bj);
                    let (m, n) = (rr.len(), cr.len());
                    let (w, ht) = if nonneg {
                        positive_factors(m, n, k, &mut rng)
                    } else {
                        mixed_sign_factors(m, n, k, &mut rng)
                    };
                    let (mut rows, mut cols, mut vals) = (Vec::new(), Vec::new(), Vec::new());
                    for (r, c, v) in blk.iter_coo() {
                        rows.push(r);
                        cols.push(c);
                        vals.push(v);
                    }
                    let mut gw_a = vec![0f32; m * k];
                    let mut ght_a = vec![0f32; n * k];
                    let ll_a = grads_sparse_coo_ref(
                        w.as_slice(), ht.as_slice(), k, &rows, &cols, &vals, 1.0, 1.0,
                        nonneg, &mut gw_a, &mut ght_a,
                    );
                    let mut gw_b = vec![0f32; m * k];
                    let mut ght_b = vec![0f32; n * k];
                    let ll_b = grads_sparse_core(
                        w.as_slice(), ht.as_slice(), k, blk, 1.0, 1.0, nonneg,
                        &mut gw_b, &mut ght_b,
                    );
                    let tag = format!("K={k} nonneg={nonneg} block=({bi},{bj})");
                    assert_close(&gw_a, &gw_b, &format!("gw {tag}"));
                    assert_close(&ght_a, &ght_b, &format!("ght {tag}"));
                    assert!(
                        (ll_a - ll_b).abs() <= 1e-3 * ll_a.abs().max(1.0),
                        "ll {tag}: {ll_a} vs {ll_b}"
                    );
                }
            }
        }
    }
}

/// Scalar and AVX2+FMA tiers produce bit-for-bit identical sparse block
/// gradients: same lane split, same reduction tree, same fused tails.
#[test]
fn sparse_scalar_and_simd_tiers_bitwise_identical() {
    if !avx2_available() {
        eprintln!("skipping: AVX2+FMA not available on this host");
        return;
    }
    let _g = TierGuard::acquire();
    let csr = movielens::movielens_like_dims(53, 47, 900, 4, 7);
    let bs = BlockedSparse::from_csr(&csr, 2).unwrap();
    let mut rng = Rng::seed_from(7);
    for &k in &KS {
        for nonneg in [false, true] {
            let blk = bs.block(0, 1);
            let (rr, cr) = block_dims(&bs, 0, 1);
            let (m, n) = (rr.len(), cr.len());
            let (w, ht) = if nonneg {
                positive_factors(m, n, k, &mut rng)
            } else {
                mixed_sign_factors(m, n, k, &mut rng)
            };
            let run = |tier: SimdTier| {
                set_tier_override(Some(tier));
                let mut gw = vec![0f32; m * k];
                let mut ght = vec![0f32; n * k];
                let ll = grads_sparse_core(
                    w.as_slice(), ht.as_slice(), k, blk, 1.0, 1.0, nonneg, &mut gw, &mut ght,
                );
                (gw, ght, ll)
            };
            let (gw_s, ght_s, ll_s) = run(SimdTier::Scalar);
            let (gw_v, ght_v, ll_v) = run(SimdTier::Avx2Fma);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&gw_s), bits(&gw_v), "gw K={k} nonneg={nonneg}");
            assert_eq!(bits(&ght_s), bits(&ght_v), "ght K={k} nonneg={nonneg}");
            assert_eq!(ll_s.to_bits(), ll_v.to_bits(), "ll K={k} nonneg={nonneg}");
        }
    }
}

/// Same bitwise contract for the tiled dense kernel, which routes its
/// mu/GW/GHt inner loops through the same ops tables.
#[test]
fn dense_scalar_and_simd_tiers_bitwise_identical() {
    if !avx2_available() {
        eprintln!("skipping: AVX2+FMA not available on this host");
        return;
    }
    let _g = TierGuard::acquire();
    let mut rng = Rng::seed_from(11);
    let (m, n) = (33usize, 29usize);
    for &k in &KS {
        for nonneg in [false, true] {
            let (w, ht) = if nonneg {
                positive_factors(m, n, k, &mut rng)
            } else {
                mixed_sign_factors(m, n, k, &mut rng)
            };
            let v = Mat::uniform(m, n, 0.0, 8.0, &mut rng);
            let run = |tier: SimdTier| {
                set_tier_override(Some(tier));
                let mut gw = vec![0f32; m * k];
                let mut ght = vec![0f32; n * k];
                let mut scratch = ScratchArena::new();
                let ll = grads_dense_tiled(
                    w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(), 1.0, 1.0,
                    nonneg, &mut gw, &mut ght, &mut scratch,
                );
                (gw, ght, ll)
            };
            let (gw_s, ght_s, ll_s) = run(SimdTier::Scalar);
            let (gw_v, ght_v, ll_v) = run(SimdTier::Avx2Fma);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&gw_s), bits(&gw_v), "gw K={k} nonneg={nonneg}");
            assert_eq!(bits(&ght_s), bits(&ght_v), "ght K={k} nonneg={nonneg}");
            assert_eq!(ll_s.to_bits(), ll_v.to_bits(), "ll K={k} nonneg={nonneg}");
        }
    }
}

fn run_sparse_chain(tier: SimdTier, threads: usize) -> (Vec<u32>, Vec<u32>) {
    set_tier_override(Some(tier));
    let csr = movielens::movielens_like_dims(40, 50, 600, 4, 9);
    let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
    let run = RunConfig::quick(40).with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
    let mut s = Psgld::new_sparse(&csr, &model, 4, run, 31)
        .unwrap()
        .with_threads(threads)
        .with_exec_mode(ExecMode::Pool);
    for t in 1..=40 {
        s.step(t);
    }
    let st = s.state();
    (
        st.w.as_slice().iter().map(|x| x.to_bits()).collect(),
        st.ht.as_slice().iter().map(|x| x.to_bits()).collect(),
    )
}

/// The acceptance pin: a sparse PSGLD chain is bitwise identical across
/// {scalar, SIMD} x {1, 2, default} workers. The (seed, t, block)-keyed
/// RNG streams make worker count irrelevant; the canonical arithmetic
/// order makes the tier irrelevant.
#[test]
fn sparse_chain_bitwise_identical_across_tiers_and_workers() {
    let _g = TierGuard::acquire();
    let mut tiers = vec![SimdTier::Scalar];
    if avx2_available() {
        tiers.push(SimdTier::Avx2Fma);
    } else {
        eprintln!("AVX2+FMA unavailable: pinning worker counts at the scalar tier only");
    }
    let reference = run_sparse_chain(SimdTier::Scalar, 1);
    for &tier in &tiers {
        for threads in [1, 2, default_threads()] {
            let got = run_sparse_chain(tier, threads);
            assert_eq!(
                reference, got,
                "chain diverged at tier={tier:?} threads={threads}"
            );
        }
    }
}

/// The batched noise slab consumes the RNG stream exactly like the old
/// per-element draw: `sgld_apply_core` equals a hand-rolled loop that
/// draws one ziggurat normal per element, across stripe boundaries and
/// for both mirror settings.
#[test]
fn batched_noise_matches_per_element_draws_bitwise() {
    for mirror in [false, true] {
        // spans two full stripes plus a ragged tail
        let n = 2 * psgld::kernels::native::NOISE_STRIPE + 123;
        let mut rng_a = Rng::seed_from(99);
        let mut rng_b = Rng::seed_from(99);
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let x0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        let (eps, scale, lam) = (0.01f32, 1.5f32, 0.3f32);
        let sd = (2.0 * eps).sqrt();

        let mut x_batched = x0.clone();
        let mut scratch = ScratchArena::new();
        sgld_apply_core(&mut x_batched, &g, eps, scale, lam, mirror, &mut rng_a, &mut scratch);

        let mut x_ref = x0;
        for i in 0..n {
            let noise = normal_ziggurat(&mut rng_b) as f32;
            let next = x_ref[i] + eps * (scale * g[i] - lam * sign0(x_ref[i])) + noise * sd;
            x_ref[i] = if mirror { next.abs() } else { next };
        }

        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&x_batched), bits(&x_ref), "mirror={mirror}");
        // and the two RNGs are in the same stream position afterwards
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "mirror={mirror}");
    }
}

/// `nonneg_hint` is the once-per-part decision both the shared-memory
/// sampler and the cluster simulator use; pin its semantics.
#[test]
fn nonneg_hint_semantics() {
    let pos = vec![0.5f32; 8];
    let neg = vec![-0.5f32; 8];
    // mirror forces the hint regardless of data
    assert!(nonneg_hint(true, &neg, &neg, 0));
    // auto-detect needs nnz to dominate the factor sizes AND all-nonneg
    assert!(nonneg_hint(false, &pos, &pos, 17));
    assert!(!nonneg_hint(false, &pos, &pos, 16));
    assert!(!nonneg_hint(false, &pos, &neg, 17));
}
