//! Failure-injection tests: wrong directories, mismatched shapes,
//! invalid configs, corrupted manifests — every user-facing error path
//! should fail loudly with an actionable message, never silently.

use std::path::{Path, PathBuf};

use psgld::cluster::{
    psgld_distributed_async, ComputeModel, CrashRule, FaultPlan, NetworkModel, TieBreak,
};
use psgld::config::{AsyncClusterConfig, ExperimentConfig, RunConfig};
use psgld::coordinator::{Checkpoint, HloPsgld};
use psgld::data::{movielens, synth};
use psgld::linalg::{Mat, StackedBlocks};
use psgld::model::NmfModel;
use psgld::partition::GridPartition;
use psgld::runtime::{Manifest, XlaRuntime};
use psgld::samplers::FactorState;
use psgld::util::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("psgld_failure_tests").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn missing_artifacts_dir_mentions_make() {
    let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
    assert!(format!("{err}").contains("make artifacts"));
}

#[test]
fn corrupted_manifest_is_rejected() {
    let dir = tmp("corrupt");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());

    // valid json, wrong version
    std::fs::write(dir.join("manifest.json"), r#"{"version": 99, "entries": []}"#).unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("version"));

    // missing required fields
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "entries": [{"name": "x"}]}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn runtime_part_update_rejects_shape_mismatch() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .find_part_update(1.0, 4, 32, 32, 16, true)
        .unwrap()
        .name
        .clone();
    let ws = StackedBlocks::zeros(4, 32, 16);
    let hs = StackedBlocks::zeros(3, 16, 32); // wrong B
    let vs = StackedBlocks::zeros(4, 32, 32);
    let err = rt
        .part_update(&entry, &ws, &hs, &vs, 0.01, 1.0, 1.0, 1.0, [0, 0])
        .unwrap_err();
    assert!(format!("{err}").contains("mismatch"));
}

#[test]
fn hlo_psgld_rejects_nonuniform_grid_and_missing_artifact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(100, 100, &model, 1); // 100/4=25 != artifact m=32
    let err = match HloPsgld::new(&dir, &data.v, &model, 4, RunConfig::quick(10), 1) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e,
    };
    let msg = format!("{err}");
    assert!(msg.contains("artifact") || msg.contains("uniform"), "{msg}");

    // non-divisible grid
    let data = synth::poisson_nmf(127, 127, &model, 1);
    let err = match HloPsgld::new(&dir, &data.v, &model, 4, RunConfig::quick(10), 1) {
        Ok(_) => panic!("expected uniform-grid error"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("uniform"), "{err}");
}

#[test]
fn grid_partition_rejects_bad_b() {
    assert!(GridPartition::new(10, 10, 0).is_err());
    assert!(GridPartition::new(10, 10, 11).is_err());
    assert!(GridPartition::new(4, 20, 5).is_err()); // B > rows
}

#[test]
fn matmul_shape_errors_are_reported() {
    let a = Mat::zeros(3, 4);
    let b = Mat::zeros(3, 4);
    let err = a.matmul(&b).unwrap_err();
    assert!(format!("{err}").contains("3x4"));
    assert!(a.matmul_abs(&b).is_err());
}

#[test]
fn experiment_config_bad_file_errors() {
    let dir = tmp("cfg");
    let path = dir.join("bad.json");
    std::fs::write(&path, r#"{"name": "x"}"#).unwrap(); // missing fields
    let err = ExperimentConfig::load(&path).unwrap_err();
    assert!(format!("{err}").contains("missing field"));
    assert!(ExperimentConfig::load(&dir.join("nope.json")).is_err());
}

#[test]
fn json_depth_and_garbage_robustness() {
    // deeply nested but valid
    let mut s = String::new();
    for _ in 0..200 {
        s.push('[');
    }
    s.push('1');
    for _ in 0..200 {
        s.push(']');
    }
    assert!(Json::parse(&s).is_ok());
    // NaN-ish / bad numbers
    assert!(Json::parse("nan").is_err());
    assert!(Json::parse("+1").is_err());
    assert!(Json::parse("01abc").is_err());
}

#[test]
fn run_config_validation_errors_are_actionable() {
    let mut rc = RunConfig::quick(10);
    rc.burn_in = 10;
    let err = rc.validate().unwrap_err();
    assert!(format!("{err}").contains("burn_in"));
}

#[test]
fn stacked_blocks_from_empty_or_ragged() {
    assert!(StackedBlocks::from_blocks(&[]).is_err());
    let blocks = vec![Mat::zeros(2, 2), Mat::zeros(3, 2)];
    assert!(StackedBlocks::from_blocks(&blocks).is_err());
}

// --- async cluster executor failure paths ----------------------------

fn sample_checkpoint() -> Checkpoint {
    let mut rng = psgld::rng::Rng::seed_from(5);
    let state = FactorState {
        w: Mat::uniform(6, 3, 0.1, 1.0, &mut rng),
        ht: Mat::uniform(8, 3, 0.1, 1.0, &mut rng),
    };
    Checkpoint::new(12, 99, &state)
}

#[test]
fn corrupted_checkpoint_fails_loudly() {
    let dir = tmp("ckpt_corrupt");
    let path = dir.join("garbage.ckpt");
    std::fs::write(&path, b"definitely not a checkpoint").unwrap();
    let msg = format!("{}", Checkpoint::load(&path).unwrap_err());
    assert!(msg.contains("magic") || msg.contains("corrupt"), "{msg}");

    // missing file: the error names the path, not just "No such file"
    let msg = format!("{}", Checkpoint::load(&dir.join("nope.ckpt")).unwrap_err());
    assert!(msg.contains("nope.ckpt"), "{msg}");
}

#[test]
fn truncated_checkpoint_fails_loudly() {
    let dir = tmp("ckpt_trunc");
    let path = dir.join("latest.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let msg = format!("{}", Checkpoint::load(&path).unwrap_err());
    assert!(
        msg.contains("truncated") || msg.contains("corrupt"),
        "truncated checkpoint error should say what to do: {msg}"
    );
    assert!(msg.contains("latest.ckpt"), "{msg}");
}

#[test]
fn fault_plan_with_nonexistent_node_is_rejected_before_the_event_loop() {
    let csr = movielens::movielens_like_dims(24, 30, 200, 3, 9);
    let model = NmfModel::poisson(3);
    let run = RunConfig::quick(10);
    let plan = FaultPlan {
        crashes: vec![CrashRule { node: 9, at_t: 2 }],
        ..Default::default()
    };
    let err = psgld_distributed_async(
        &csr,
        &model,
        4,
        &run,
        1,
        &NetworkModel::paper_cluster(),
        &ComputeModel::paper_node(),
        &AsyncClusterConfig::default(),
        &plan,
        TieBreak::Fifo,
        |_| 0.0,
    )
    .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("node 9"), "{msg}");
    assert!(msg.contains("only 4 nodes"), "{msg}");
}

#[test]
fn async_cluster_config_validation_is_actionable() {
    let bad = AsyncClusterConfig { max_retries: 0, ..Default::default() };
    let msg = format!("{}", bad.validate().unwrap_err());
    assert!(msg.contains("hang"), "max_retries=0 would hang forever: {msg}");

    let bad = AsyncClusterConfig {
        checkpoint_dir: Some("/tmp/x".into()),
        checkpoint_every: 0,
        ..Default::default()
    };
    let msg = format!("{}", bad.validate().unwrap_err());
    assert!(msg.contains("checkpoint_every"), "{msg}");

    let bad = AsyncClusterConfig { msg_timeout_s: 0.0, ..Default::default() };
    assert!(bad.validate().is_err());
    let bad = AsyncClusterConfig { retry_backoff: 0.5, ..Default::default() };
    assert!(bad.validate().is_err());
}
