//! Statistical convergence tests — the paper's Theorem 1 says the PSGLD
//! chain targets the Bayesian posterior. We cannot verify an asymptotic
//! statement exactly, but we can check strong necessary conditions on a
//! tiny conjugate-ish problem where Gibbs provides the ground truth:
//! posterior means and spreads of summary statistics must agree between
//! PSGLD and Gibbs within Monte Carlo error.

use psgld::cluster::{
    psgld_distributed_async, psgld_distributed_full, ComputeModel, FaultPlan, NetworkModel,
    StragglerRule, TieBreak,
};
use psgld::config::{AsyncClusterConfig, RunConfig, StepSchedule};
use psgld::data::sparse::Csr;
use psgld::data::synth;
use psgld::metrics::{rmse_sparse, SummaryStats};
use psgld::model::NmfModel;
use psgld::samplers::{run_sampler, GibbsPoisson, Psgld, Sampler};

/// Posterior mean of the reconstruction mu summed over entries — a
/// scalar summary whose posterior is well-identified (unlike W, H which
/// suffer permutation/scale non-identifiability).
fn recon_mass_chain<S: Sampler>(s: &mut S, t_total: u64, burn: u64) -> Vec<f64> {
    let mut vals = Vec::new();
    for t in 1..=t_total {
        s.step(t);
        if t > burn {
            let recon = s.state().reconstruct();
            vals.push(recon.as_slice().iter().map(|&x| x as f64).sum::<f64>());
        }
    }
    vals
}

#[test]
fn psgld_posterior_matches_gibbs_on_small_problem() {
    let model = NmfModel::poisson(3);
    let data = synth::poisson_nmf(16, 16, &model, 555);
    let data_mass: f64 = data.v.as_slice().iter().map(|&x| x as f64).sum();

    let mut gibbs = GibbsPoisson::new(&data.v, &model, 1);
    let g_chain = recon_mass_chain(&mut gibbs, 1_500, 500);
    let g = SummaryStats::from_chain(&g_chain);

    let run = RunConfig::quick(8_000)
        .with_step(StepSchedule::Polynomial { a: 0.004, b: 0.51 });
    let mut psgld_s = Psgld::new(&data.v, &model, 4, run, 2);
    let p_chain = recon_mass_chain(&mut psgld_s, 8_000, 4_000);
    let p = SummaryStats::from_chain(&p_chain);

    // Poisson posterior mass concentrates near the observed mass
    assert!(
        (g.mean - data_mass).abs() < 0.05 * data_mass,
        "gibbs mass {} vs data {}",
        g.mean,
        data_mass
    );
    // PSGLD must land on the same posterior mean within a few MC sds
    let tol = 4.0 * (g.sd / (g.ess.max(4.0)).sqrt() + p.sd / (p.ess.max(4.0)).sqrt())
        + 0.01 * data_mass;
    assert!(
        (g.mean - p.mean).abs() < tol,
        "psgld {} vs gibbs {} (tol {tol})",
        p.mean,
        g.mean
    );
    // and its posterior spread must be the same order (within 3x)
    assert!(
        p.sd < 3.0 * g.sd + 1.0 && g.sd < 3.0 * p.sd + 1.0,
        "sd mismatch: psgld {} gibbs {}",
        p.sd,
        g.sd
    );
}

#[test]
fn decreasing_step_reduces_discretisation_bias() {
    // With a larger constant step the Langevin discretisation inflates
    // the stationary spread; the (a/t)^b schedule should end tighter
    // than a large constant step on the same problem.
    let model = NmfModel::poisson(2);
    let data = synth::poisson_nmf(12, 12, &model, 7);

    let run_poly = RunConfig::quick(4_000)
        .with_step(StepSchedule::Polynomial { a: 0.004, b: 0.51 });
    let mut a = Psgld::new(&data.v, &model, 3, run_poly, 3);
    let chain_a = recon_mass_chain(&mut a, 4_000, 2_000);
    let sa = SummaryStats::from_chain(&chain_a);

    let run_const = RunConfig::quick(4_000)
        .with_step(StepSchedule::Constant { eps: 0.02 });
    let mut b = Psgld::new(&data.v, &model, 3, run_const, 3);
    let chain_b = recon_mass_chain(&mut b, 4_000, 2_000);
    let sb = SummaryStats::from_chain(&chain_b);

    assert!(
        sa.sd < sb.sd,
        "polynomial schedule sd {} should be below constant-step sd {}",
        sa.sd,
        sb.sd
    );
}

#[test]
fn bounded_staleness_matches_synchronous_posterior_mean() {
    // Bounded-staleness PSGLD targets the same posterior: the
    // posterior-mean RMSE of the reconstruction must stay within a
    // tolerance band of the synchronous chain for tau in {1, 4}.
    //
    // Staleness is content lineage (it accumulates across stale
    // executions): with B = 4, tau = 1 only admits the init-copy
    // transient and hand-offs that inherit it, so the chain stays
    // near-synchronous and paces the straggler from the first lap,
    // while tau = 4 = B admits genuinely lap-stale reuse — the regime
    // this test is really about. A permanent straggler makes sure the
    // stale path is exercised rather than everyone keeping pace.
    let b = 4;
    let model = NmfModel::poisson(3);
    let data = synth::poisson_nmf(16, 16, &model, 321);
    // densely-observed sparse matrix: every entry (zeros included) is a
    // Poisson observation, so the sparse chain solves the dense problem
    let mut trip: Vec<(u32, u32, f32)> = Vec::new();
    for i in 0..16usize {
        for (j, &val) in data.v.row(i).iter().enumerate() {
            trip.push((i as u32, j as u32, val));
        }
    }
    let csr = Csr::from_triplets(16, 16, &mut trip).unwrap();

    let t_total = 2_000u64;
    let burn = 1_000u64;
    let run = RunConfig::quick(t_total)
        .with_step(StepSchedule::Polynomial { a: 0.004, b: 0.51 })
        .with_monitor_every(2);
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();

    let sync = psgld_distributed_full(&csr, &model, b, &run, 17, &net, &compute, |s| {
        rmse_sparse(&s.w, &s.h(), &csr)
    })
    .unwrap();
    let sync_rmse = sync.trace.expect("full fidelity").mean_after(burn);

    let plan = FaultPlan {
        stragglers: vec![StragglerRule { node: 1, from_t: 1, to_t: t_total, factor: 8.0 }],
        ..Default::default()
    };
    for tau in [1u64, 4] {
        let cfg = AsyncClusterConfig::default().with_tau(tau);
        let rep = psgld_distributed_async(
            &csr, &model, b, &run, 17, &net, &compute, &cfg, &plan, TieBreak::Fifo,
            |s| rmse_sparse(&s.w, &s.h(), &csr),
        )
        .unwrap();
        let stale_rmse = rep.trace.mean_after(burn);
        let tol = 0.20 * sync_rmse + 0.05;
        assert!(
            (stale_rmse - sync_rmse).abs() < tol,
            "tau={tau}: posterior-mean RMSE {stale_rmse} drifted from synchronous \
             {sync_rmse} (tol {tol})"
        );
        if tau == 4 {
            assert!(
                rep.ledger.max_staleness() > 0,
                "tau=4 with a straggler must actually run the stale path"
            );
        }
    }
}

#[test]
fn loglik_trace_is_stationary_after_burnin() {
    // post burn-in, the loglik trace should not trend: first and second
    // half means agree within the chain's own spread
    let model = NmfModel::poisson(4);
    let data = synth::poisson_nmf(32, 32, &model, 9);
    let run = RunConfig::quick(3_000)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
        .with_monitor_every(10);
    let mut s = Psgld::new(&data.v, &model, 4, run.clone(), 4);
    let res = run_sampler(&mut s, &run, |st| model.loglik_dense(&st.w, &st.h(), &data.v));
    let vals: Vec<f64> = res
        .trace
        .iters
        .iter()
        .zip(&res.trace.values)
        .filter(|(&it, _)| it > 1_500)
        .map(|(_, &v)| v)
        .collect();
    let half = vals.len() / 2;
    let m1 = vals[..half].iter().sum::<f64>() / half as f64;
    let m2 = vals[half..].iter().sum::<f64>() / (vals.len() - half) as f64;
    let sd = SummaryStats::from_chain(&vals).sd;
    assert!(
        (m1 - m2).abs() < 3.0 * sd + 0.002 * m1.abs(),
        "trend detected: {m1} vs {m2} (sd {sd})"
    );
}
