//! Steady-state allocation accounting for the PSGLD hot path.
//!
//! The persistent worker pool + scratch-arena design promises that once
//! a sampler is warmed up (pool spawned, arenas grown to their final
//! size, one full cyclic part sweep done), `Psgld::step` performs ZERO
//! heap allocations — on the caller thread and on every worker thread.
//! This test pins that property with a counting global allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::model::NmfModel;
use psgld::samplers::{Psgld, Sampler};

/// Counts every allocation (alloc, zeroed alloc, realloc) process-wide.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

const B: usize = 4;

fn assert_steady_state_alloc_free(mut sampler: Psgld, label: &str) {
    // Warmup: pool threads spawn lazily-initialised statics, arenas grow
    // to their high-water mark, and a full cyclic part sweep touches
    // every (block, stripe) size combination.
    let warmup = (4 * B) as u64;
    for t in 1..=warmup {
        sampler.step(t);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    let steps = 32u64;
    for t in warmup + 1..=warmup + steps {
        sampler.step(t);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{label}: {} heap allocations in {steps} steady-state steps",
        after - before
    );
    // sanity: the chain actually moved
    assert!(sampler.state().w.as_slice().iter().all(|x| x.is_finite()));
}

fn run_cfg() -> RunConfig {
    RunConfig::quick(1_000).with_step(StepSchedule::Polynomial { a: 0.005, b: 0.51 })
}

// One #[test] covering all scenarios: the allocation counter is
// process-wide, so scenarios must run sequentially in a binary with no
// other concurrently-running tests.
#[test]
fn psgld_step_is_allocation_free_in_steady_state() {
    // Pin the obs level rather than trusting the environment: the
    // instrumented hot path must stay zero-alloc with obs off, and the
    // env var must not silently weaken this test.
    psgld::obs::set_level_override(Some(psgld::obs::ObsLevel::Off));

    // dense path, 1 and 2 workers
    for threads in [1usize, 2] {
        let model = NmfModel::poisson(8);
        let data = synth::poisson_nmf(64, 64, &model, 3 + threads as u64);
        let s = Psgld::new(&data.v, &model, B, run_cfg(), threads as u64)
            .with_threads(threads);
        assert_steady_state_alloc_free(s, &format!("dense/threads={threads}"));
    }

    // sparse path, 1 and 2 workers
    use psgld::data::movielens;
    let csr = movielens::movielens_like_dims(48, 64, 800, 4, 5);
    let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
    for threads in [1usize, 2] {
        let s = Psgld::new_sparse(&csr, &model, B, run_cfg(), 6)
            .unwrap()
            .with_threads(threads);
        assert_steady_state_alloc_free(s, &format!("sparse/threads={threads}"));
    }

    // at `counters` the spans and counters record into pre-registered
    // per-thread atomic shards: still zero steady-state allocations
    // (the once-per-thread shard registration happens during warmup)
    psgld::obs::set_level_override(Some(psgld::obs::ObsLevel::Counters));
    for threads in [1usize, 2] {
        let s = Psgld::new_sparse(&csr, &model, B, run_cfg(), 6)
            .unwrap()
            .with_threads(threads);
        assert_steady_state_alloc_free(s, &format!("sparse+counters/threads={threads}"));
    }
    psgld::obs::set_level_override(None);
}
