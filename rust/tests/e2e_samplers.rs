//! End-to-end sampler integration tests: every method on the same small
//! Poisson-NMF problem, checking convergence quality relationships the
//! paper asserts (PSGLD ≈ Gibbs quality; everything beats its own random
//! init; HLO and native backends behave alike).

use std::path::{Path, PathBuf};

use psgld::config::{RunConfig, StepSchedule};
use psgld::coordinator::HloPsgld;
use psgld::data::synth;
use psgld::model::NmfModel;
use psgld::samplers::{
    run_sampler, Dsgd, GibbsPoisson, Ld, Psgld, Sampler, Sgld,
};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

/// Shared workload: 64x64 Poisson-NMF, K=8.
fn workload() -> (NmfModel, psgld::data::DenseDataset) {
    let model = NmfModel::poisson(8);
    let data = synth::poisson_nmf(64, 64, &model, 1234);
    (model, data)
}

#[test]
fn all_native_samplers_improve_and_reach_similar_quality() {
    let (model, data) = workload();
    let run = RunConfig::quick(400).with_monitor_every(50);

    let mut results = Vec::new();

    let mut psgld_s = Psgld::new(
        &data.v, &model, 4,
        run.clone().with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 }), 7,
    );
    results.push((
        "psgld",
        run_sampler(&mut psgld_s, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v)),
    ));

    let mut gibbs = GibbsPoisson::new(&data.v, &model, 8);
    results.push((
        "gibbs",
        run_sampler(&mut gibbs, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v)),
    ));

    let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 5e-5 }, 9);
    results.push((
        "ld",
        run_sampler(&mut ld, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v)),
    ));

    let mut sgld = Sgld::new(
        &data.v, &model, 64 * 64 / 32,
        StepSchedule::Polynomial { a: 2e-4, b: 0.51 }, 10,
    );
    results.push((
        "sgld",
        run_sampler(&mut sgld, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v)),
    ));

    for (name, res) in &results {
        assert!(
            res.trace.last_value() > res.trace.values[0],
            "{name}: {} -> {}",
            res.trace.values[0],
            res.trace.last_value()
        );
    }

    // PSGLD must reach Gibbs-like quality (the paper's headline claim:
    // "virtually the same quality"). Tolerance: within 5% of the gap
    // from the random init.
    let get = |n: &str| {
        results
            .iter()
            .find(|(name, _)| *name == n)
            .map(|(_, r)| r.trace.mean_after(run.burn_in))
            .unwrap()
    };
    let init = results[0].1.trace.values[0];
    let (psgld_ll, gibbs_ll) = (get("psgld"), get("gibbs"));
    let gap = (gibbs_ll - init).abs().max(1.0);
    assert!(
        (gibbs_ll - psgld_ll).abs() < 0.10 * gap,
        "psgld {psgld_ll} vs gibbs {gibbs_ll} (init {init})"
    );
}

#[test]
fn psgld_is_much_faster_per_iteration_than_gibbs() {
    // the Fig 2(a) timing claim, reproduced as per-iteration work:
    // PSGLD touches N/B entries/iter, Gibbs does N multinomials of
    // size K. Wall-clock ratio must be large even on one core.
    let (model, data) = workload();
    let run = RunConfig::quick(30).with_monitor_every(30);
    let mut p = Psgld::new(&data.v, &model, 4, run.clone(), 1);
    let mut g = GibbsPoisson::new(&data.v, &model, 2);
    let rp = run_sampler(&mut p, &run, |_| 0.0);
    let rg = run_sampler(&mut g, &run, |_| 0.0);
    let ratio = rg.sampling_seconds / rp.sampling_seconds.max(1e-9);
    assert!(
        ratio > 3.0,
        "gibbs {}s vs psgld {}s (ratio {ratio})",
        rg.sampling_seconds,
        rp.sampling_seconds
    );
}

#[test]
fn dsgd_converges_but_collapses_variance() {
    // DSGD is the noise-free limit: same machinery, deterministic —
    // posterior spread of the chain shrinks to ~0 while PSGLD keeps
    // sampling noise (it is an MCMC chain, not an optimiser).
    let (model, data) = workload();
    let run = RunConfig::quick(300)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
        .with_monitor_every(10);
    let mut d = Dsgd::new(&data.v, &model, 4, run.clone(), 11);
    let rd = run_sampler(&mut d, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));
    let mut p = Psgld::new(&data.v, &model, 4, run.clone(), 11);
    let rp = run_sampler(&mut p, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));

    let tail = |v: &[f64]| {
        let t = &v[v.len().saturating_sub(8)..];
        let m = t.iter().sum::<f64>() / t.len() as f64;
        (m, t.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / t.len() as f64)
    };
    let (_, var_d) = tail(&rd.trace.values);
    let (_, var_p) = tail(&rp.trace.values);
    assert!(
        var_p > 2.0 * var_d,
        "psgld tail var {var_p} should exceed dsgd tail var {var_d}"
    );
}

#[test]
fn hlo_psgld_matches_native_convergence() {
    let Some(dir) = artifacts_dir() else { return };
    // quickstart artifact geometry: I=J=128, K=16, B=4
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, 77);
    let run = RunConfig::quick(120)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
        .with_monitor_every(20);

    let mut hlo = HloPsgld::new(&dir, &data.v, &model, 4, run.clone(), 5).unwrap();
    let r_hlo = run_sampler(&mut hlo, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));

    let mut native = Psgld::new(&data.v, &model, 4, run.clone(), 5);
    let r_nat = run_sampler(&mut native, &run, |s| model.loglik_dense(&s.w, &s.h(), &data.v));

    assert!(r_hlo.trace.last_value() > r_hlo.trace.values[0]);
    // different RNG streams, same dynamics: final logliks agree within
    // 5% of the improvement
    let improve = (r_nat.trace.last_value() - r_nat.trace.values[0]).abs();
    let gap = (r_hlo.trace.last_value() - r_nat.trace.last_value()).abs();
    assert!(
        gap < 0.1 * improve,
        "hlo {} vs native {} (improvement {improve})",
        r_hlo.trace.last_value(),
        r_nat.trace.last_value()
    );
    // mirrored chain stays non-negative
    assert!(hlo.state().w.as_slice().iter().all(|&x| x >= 0.0));
}

#[test]
fn hlo_loglik_monitor_agrees_with_native() {
    let Some(dir) = artifacts_dir() else { return };
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, 78);
    let run = RunConfig::quick(10);
    let mut hlo = HloPsgld::new(&dir, &data.v, &model, 4, run, 6).unwrap();
    for t in 1..=3 {
        hlo.step(t);
    }
    let via_hlo = hlo.loglik();
    let via_native = model.loglik_dense(&hlo.state().w, &hlo.state().h(), &data.v);
    let rel = (via_hlo - via_native).abs() / via_native.abs().max(1.0);
    assert!(rel < 1e-4, "{via_hlo} vs {via_native}");
}
