//! Bench for Fig. 2(b): per-iteration cost under the compound-Poisson
//! observation model (β = 0.5) — checks that the generic-β gradient
//! path (powf) stays within a small factor of the specialised β = 1.
//!
//! Run: `cargo bench --bench fig2b_compound`

mod bench_util;
use bench_util::{header, report, time_it};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::model::NmfModel;
use psgld::samplers::{Ld, Psgld, Sampler, Sgld};

fn main() {
    header("Fig 2(b): per-iteration cost, compound Poisson (beta=0.5)");
    let i = 512usize;
    let model = NmfModel::compound_poisson(32);
    let data = synth::compound_poisson_nmf(i, i, &model, 1);
    let n = (i * i) as f64;
    let b = i / 32;

    let run = RunConfig::quick(100)
        .with_step(StepSchedule::Polynomial { a: 0.016 / b as f64, b: 0.51 });
    let mut p = Psgld::new(&data.v, &model, b, run.clone(), 2);
    let mut t = 0u64;
    let s = time_it(3, 10, || {
        t += 1;
        p.step(t);
    });
    report("psgld/beta=0.5", s, Some((n / b as f64, "entries")));

    // beta = 1 on the same data scale for the specialisation gap
    let model1 = NmfModel::poisson(32);
    let data1 = synth::poisson_nmf(i, i, &model1, 1);
    let mut p1 = Psgld::new(&data1.v, &model1, b, run.clone(), 2);
    let mut t = 0u64;
    let s1 = time_it(3, 10, || {
        t += 1;
        p1.step(t);
    });
    report("psgld/beta=1 (specialised)", s1, Some((n / b as f64, "entries")));
    psgld::log_info!("generic-beta overhead: {:.2}x", s / s1);

    let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 2e-5 }, 3);
    let mut t = 0u64;
    let s = time_it(1, 3, || {
        t += 1;
        ld.step(t);
    });
    report("ld/beta=0.5", s, Some((n, "entries")));

    let mut sgld = Sgld::new(
        &data.v, &model, i * i / 32,
        StepSchedule::Polynomial { a: 1e-4, b: 0.51 }, 4,
    );
    let mut t = 0u64;
    let s = time_it(1, 5, || {
        t += 1;
        sgld.step(t);
    });
    report("sgld/beta=0.5", s, Some((n / 32.0, "entries")));
}
