//! Micro-benchmarks of the hot kernels (the §Perf working set):
//! dense/sparse block gradients by K, the Langevin noise path, the
//! Gibbs multinomial inner loop, and the HLO dispatch overhead.
//!
//! Run: `cargo bench --bench kernels`

mod bench_util;
use bench_util::{header, report, time_it, JsonSink};

use psgld::data::movielens;
use psgld::data::sparse::BlockedSparse;
use psgld::kernels::{grads_dense_core, grads_dense_tiled, grads_sparse_core, sgld_apply_core};
use psgld::linalg::{Mat, StackedBlocks};
use psgld::rng::{Dist, Rng};
use psgld::util::parallel::ScratchArena;

fn main() {
    let mut rng = Rng::seed_from(1);
    let mut json = JsonSink::at_repo_root("BENCH_kernels.json");

    header("dense block gradients (64x64 block)");
    for &k in &[8usize, 16, 32, 50, 64] {
        let m = 64;
        let w = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let ht = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let v = Mat::uniform(m, m, 0.0, 8.0, &mut rng);
        let mut gw = vec![0f32; m * k];
        let mut ght = vec![0f32; m * k];
        let s = time_it(5, 30, || {
            gw.fill(0.0);
            ght.fill(0.0);
            grads_dense_core(
                w.as_slice(), m, ht.as_slice(), m, k, v.as_slice(), 1.0, 1.0,
                &mut gw, &mut ght,
            );
        });
        report(
            &format!("dense_grads/K={k}"),
            s,
            Some(((m * m) as f64, "entries")),
        );
        json.push(&format!("dense_grads/K={k}"), s, Some(((m * m) as f64, "entries")), 1);
    }

    header("tiled dense gradients: arena-reuse + nonneg fast path (128x128, K=32)");
    {
        let (m, n, k) = (128usize, 128usize, 32usize);
        let w = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
        let ht = Mat::uniform(n, k, 0.1, 1.0, &mut rng);
        let v = Mat::uniform(m, n, 0.0, 8.0, &mut rng);
        let mut gw = vec![0f32; m * k];
        let mut ght = vec![0f32; n * k];
        // per-call allocation baseline (what the spawn-per-step regime did)
        let s_alloc = time_it(5, 30, || {
            gw.fill(0.0);
            ght.fill(0.0);
            grads_dense_core(
                w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(), 1.0, 1.0,
                &mut gw, &mut ght,
            );
        });
        report("dense_grads/alloc-per-call", s_alloc, Some(((m * n) as f64, "entries")));
        json.push("dense_grads/alloc-per-call", s_alloc, Some(((m * n) as f64, "entries")), 1);
        let mut scratch = ScratchArena::new();
        let s_arena = time_it(5, 30, || {
            gw.fill(0.0);
            ght.fill(0.0);
            grads_dense_tiled(
                w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(), 1.0, 1.0,
                false, &mut gw, &mut ght, &mut scratch,
            );
        });
        report("dense_grads/arena-reuse", s_arena, Some(((m * n) as f64, "entries")));
        json.push("dense_grads/arena-reuse", s_arena, Some(((m * n) as f64, "entries")), 1);
        let s_nonneg = time_it(5, 30, || {
            gw.fill(0.0);
            ght.fill(0.0);
            grads_dense_tiled(
                w.as_slice(), m, ht.as_slice(), n, k, v.as_slice(), 1.0, 1.0,
                true, &mut gw, &mut ght, &mut scratch,
            );
        });
        report("dense_grads/arena+nonneg", s_nonneg, Some(((m * n) as f64, "entries")));
        json.push("dense_grads/arena+nonneg", s_nonneg, Some(((m * n) as f64, "entries")), 1);
        psgld::log_info!(
            "arena reuse speedup over alloc-per-call: {:.2}x (nonneg path {:.2}x)",
            s_alloc / s_arena,
            s_alloc / s_nonneg
        );
    }

    header("sparse block gradients (movielens-like block, K=50)");
    let csr = movielens::movielens_like(0.05, 50, 2);
    let bs = BlockedSparse::from_csr(&csr, 4).unwrap();
    let blk = bs.block(0, 0);
    let m = bs.grid().row_range(0).len();
    let n = bs.grid().col_range(0).len();
    let w = Mat::uniform(m, 50, 0.1, 1.0, &mut rng);
    let ht = Mat::uniform(n, 50, 0.1, 1.0, &mut rng);
    let mut gw = vec![0f32; m * 50];
    let mut ght = vec![0f32; n * 50];
    let s = time_it(3, 20, || {
        gw.fill(0.0);
        ght.fill(0.0);
        grads_sparse_core(
            w.as_slice(), ht.as_slice(), 50, blk, 1.0, 1.0, false, &mut gw, &mut ght,
        );
    });
    report("sparse_grads/K=50", s, Some((blk.nnz() as f64, "nnz")));
    json.push("sparse_grads/K=50", s, Some((blk.nnz() as f64, "nnz")), 1);
    let s = time_it(3, 20, || {
        gw.fill(0.0);
        ght.fill(0.0);
        grads_sparse_core(
            w.as_slice(), ht.as_slice(), 50, blk, 1.0, 1.0, true, &mut gw, &mut ght,
        );
    });
    report("sparse_grads/K=50+nonneg-hint", s, Some((blk.nnz() as f64, "nnz")));
    json.push("sparse_grads/K=50+nonneg-hint", s, Some((blk.nnz() as f64, "nnz")), 1);

    header("SGLD apply (drift + batched Langevin noise + mirror)");
    let mut noise_scratch = ScratchArena::new();
    for &len in &[1usize << 14, 1 << 18, 1 << 21] {
        let g = vec![0.5f32; len];
        let mut x = vec![0.1f32; len];
        let s = time_it(3, 20, || {
            sgld_apply_core(&mut x, &g, 0.01, 1.0, 1.0, true, &mut rng, &mut noise_scratch);
        });
        report(&format!("sgld_apply/{len}"), s, Some((len as f64, "entries")));
        json.push(&format!("sgld_apply/{len}"), s, Some((len as f64, "entries")), 1);
    }

    header("distribution samplers");
    let s = time_it(3, 10, || {
        let mut acc = 0f64;
        for _ in 0..100_000 {
            acc += rng.normal();
        }
        std::hint::black_box(acc);
    });
    report("normal (polar)", s, Some((1e5, "draws")));
    let s = time_it(3, 10, || {
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc += rng.poisson(8.0);
        }
        std::hint::black_box(acc);
    });
    report("poisson(8)", s, Some((1e5, "draws")));
    let s = time_it(3, 10, || {
        let mut out = [0u64; 32];
        let w = [1.0f64; 32];
        for _ in 0..10_000 {
            rng.multinomial(30, &w, &mut out);
        }
        std::hint::black_box(out);
    });
    report("multinomial(30, K=32) [gibbs inner]", s, Some((1e4, "draws")));
    let s = time_it(3, 10, || {
        let mut acc = 0f64;
        for _ in 0..100_000 {
            acc += rng.gamma(2.5, 1.0);
        }
        std::hint::black_box(acc);
    });
    report("gamma(2.5)", s, Some((1e5, "draws")));

    // HLO dispatch overhead, when artifacts exist
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        header("HLO batched part-update dispatch (B=4, 32x32, K=16)");
        let mut rt = psgld::runtime::XlaRuntime::new(dir).unwrap();
        let entry = rt
            .manifest()
            .find_part_update(1.0, 4, 32, 32, 16, true)
            .unwrap()
            .name
            .clone();
        let mk = |rng: &mut Rng, b: usize, r: usize, c: usize| {
            let blocks: Vec<Mat> =
                (0..b).map(|_| Mat::uniform(r, c, 0.1, 1.0, rng)).collect();
            StackedBlocks::from_blocks(&blocks).unwrap()
        };
        let ws = mk(&mut rng, 4, 32, 16);
        let hs = mk(&mut rng, 4, 16, 32);
        let vs = mk(&mut rng, 4, 32, 32);
        rt.part_update(&entry, &ws, &hs, &vs, 0.01, 4.0, 1.0, 1.0, [1, 2])
            .unwrap();
        let s = time_it(3, 30, || {
            rt.part_update(&entry, &ws, &hs, &vs, 0.01, 4.0, 1.0, 1.0, [1, 2])
                .unwrap();
        });
        report("part_update dispatch", s, Some(((4 * 32 * 32) as f64, "entries")));
        json.push("part_update_dispatch", s, Some(((4 * 32 * 32) as f64, "entries")), 1);
    }

    json.write();
}
