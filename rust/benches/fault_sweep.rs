//! Robustness sweep for the asynchronous fault-injecting cluster
//! executor: virtual-time throughput and held-out log-likelihood across
//! crash rate × staleness bound `tau`, written to `BENCH_fault.json`.
//!
//! The `tau = 0` / no-fault cell is asserted bitwise-equal to the
//! synchronous simulator before anything is reported — the sweep is
//! meaningless if the baseline drifts.
//!
//! Run: `cargo bench --bench fault_sweep` (full grid)
//!      `cargo bench --bench fault_sweep -- --smoke` (tiny CI grid)

mod bench_util;
use bench_util::header;

use std::io::Write;
use std::path::PathBuf;

use psgld::cluster::{
    psgld_distributed_async, psgld_distributed_full, ComputeModel, FaultPlan, FaultRates,
    NetworkModel, TieBreak,
};
use psgld::config::{AsyncClusterConfig, RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::data::sparse::Csr;
use psgld::metrics::loglik_sparse;
use psgld::model::NmfModel;

/// Deterministic ~10% holdout split by entry index.
fn split_holdout(csr: &Csr) -> (Csr, Csr) {
    let rows = csr.rows();
    let cols = csr.cols();
    let mut train: Vec<(u32, u32, f32)> = Vec::new();
    let mut held: Vec<(u32, u32, f32)> = Vec::new();
    let mut idx = 0u64;
    for i in 0..rows {
        for (j, val) in csr.row(i) {
            if idx % 10 == 3 {
                held.push((i as u32, j, val));
            } else {
                train.push((i as u32, j, val));
            }
            idx += 1;
        }
    }
    (
        Csr::from_triplets(rows, cols, &mut train).expect("train split"),
        Csr::from_triplets(rows, cols, &mut held).expect("holdout split"),
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = 4usize;
    let (t_total, taus, crash_rates): (u64, Vec<u64>, Vec<f64>) = if smoke {
        (40, vec![0, 4], vec![0.0, 0.02])
    } else {
        (200, vec![0, 1, 4, 8], vec![0.0, 0.005, 0.02, 0.05])
    };

    let csr = movielens::movielens_like_dims(64, 80, 1600, 4, 21);
    let (train, held) = split_holdout(&csr);
    let model = NmfModel::poisson(4).with_priors(2.0, 2.0);
    let run = RunConfig::quick(t_total).with_step(StepSchedule::Polynomial { a: 0.01, b: 0.51 });
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let seed = 4242u64;

    // --- baseline contract: tau=0 + no faults == synchronous, bitwise
    let sync = psgld_distributed_full(&train, &model, b, &run, seed, &net, &compute, |_| 0.0)
        .expect("sync baseline");
    let base_cfg = AsyncClusterConfig::default().with_checkpoint_every(t_total / 4);
    let base = psgld_distributed_async(
        &train,
        &model,
        b,
        &run,
        seed,
        &net,
        &compute,
        &base_cfg,
        &FaultPlan::empty(),
        TieBreak::Fifo,
        |_| 0.0,
    )
    .expect("async baseline");
    let sync_state = sync.state.expect("full fidelity keeps state");
    assert_eq!(
        base.state.w, sync_state.w,
        "tau=0/no-fault async W drifted from the synchronous simulator"
    );
    assert_eq!(
        base.state.ht, sync_state.ht,
        "tau=0/no-fault async H drifted from the synchronous simulator"
    );
    psgld::log_info!("baseline check: tau=0/no-fault async == synchronous (bitwise) ✓");

    header(&format!(
        "fault sweep (B={b}, T={t_total}, {} train / {} holdout nnz{})",
        train.nnz(),
        held.nnz(),
        if smoke { ", --smoke" } else { "" }
    ));
    psgld::log_info!(
        "{:>5} {:>11} {:>12} {:>14} {:>16} {:>10} {:>9} {:>12}",
        "tau", "crash_rate", "virt_sec", "iters/vsec", "holdout_loglik", "recov", "max_stale",
        "stall_sec"
    );

    let mut rows: Vec<String> = Vec::new();
    for &tau in &taus {
        for &rate in &crash_rates {
            let plan = if rate == 0.0 {
                FaultPlan::empty()
            } else {
                let rates = FaultRates {
                    crash_prob: rate,
                    straggler_prob: 0.02,
                    drop_prob: 0.01,
                    delay_prob: 0.02,
                    ..Default::default()
                };
                FaultPlan::seeded(seed ^ tau ^ (rate * 1e4) as u64, b, t_total, &rates)
            };
            let cfg = AsyncClusterConfig::default()
                .with_tau(tau)
                .with_checkpoint_every((t_total / 8).max(1));
            let rep = match psgld_distributed_async(
                &train,
                &model,
                b,
                &run,
                seed,
                &net,
                &compute,
                &cfg,
                &plan,
                TieBreak::Fifo,
                |_| 0.0,
            ) {
                Ok(r) => r,
                Err(e) => {
                    psgld::log_warn!("{tau:>5} {rate:>11.3}  failed: {e}");
                    continue;
                }
            };
            let ll = loglik_sparse(&rep.state.w, &rep.state.h(), &held, model.beta, model.phi);
            let throughput = rep.iterations as f64 / rep.virtual_seconds.max(1e-12);
            psgld::log_info!(
                "{tau:>5} {rate:>11.3} {:>12.4} {:>14.1} {:>16.2} {:>10} {:>9} {:>12.4}",
                rep.virtual_seconds,
                throughput,
                ll,
                rep.recoveries,
                rep.ledger.max_staleness(),
                rep.stall_seconds
            );
            rows.push(format!(
                "{{\"tau\":{tau},\"crash_rate\":{rate},\"virtual_seconds\":{:.6},\
                 \"iters_per_vsec\":{throughput:.3},\"holdout_loglik\":{ll:.4},\
                 \"recoveries\":{},\"checkpoints\":{},\"max_staleness\":{},\
                 \"stale_fraction\":{:.4},\"stall_seconds\":{:.6},\
                 \"messages_dropped\":{},\"retries\":{},\"executed_iterations\":{}}}",
                rep.virtual_seconds,
                rep.recoveries,
                rep.checkpoints_taken,
                rep.ledger.max_staleness(),
                rep.ledger.stale_fraction(),
                rep.stall_seconds,
                rep.messages_dropped,
                rep.retries,
                rep.executed_iterations,
            ));
        }
    }

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_fault.json");
    let body = format!("[\n  {}\n]\n", rows.join(",\n  "));
    match std::fs::File::create(&path).and_then(|mut f| f.write_all(body.as_bytes())) {
        Ok(()) => psgld::log_info!("\nwrote {}", path.display()),
        Err(e) => psgld::log_error!("\ncould not write {}: {e}", path.display()),
    }

    // Per-node counters of the fault-free baseline, one JSON object per
    // line (schema documented on `Trace::write_node_stats_jsonl`).
    let nodes_path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_fault_nodes.jsonl");
    match base.trace.write_node_stats_jsonl(&nodes_path) {
        Ok(()) => psgld::log_info!("wrote {}", nodes_path.display()),
        Err(e) => psgld::log_error!("could not write {}: {e}", nodes_path.display()),
    }
}
