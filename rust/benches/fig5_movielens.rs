//! Bench for Fig. 5: sparse-matrix PSGLD vs DSGD per-iteration cost on
//! the MovieLens-like workload (K = 50, B = 15). The paper's claim is
//! runtime parity; the delta measured here is exactly the Langevin
//! noise generation, broken out separately.
//!
//! Also carries the §Perf before/after microbench for the sparse
//! block-gradient kernel: the pre-PR local-index COO scalar walk vs.
//! the block-local CSR kernel at the scalar and SIMD-dispatched tiers.
//! Writes `BENCH_fig5.json` at the repo root.
//!
//! Run: `cargo bench --bench fig5_movielens`

mod bench_util;
use bench_util::{header, is_smoke, report, time_it, write_obs_summary, JsonSink};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::data::sparse::BlockedSparse;
use psgld::kernels::{
    active_tier, grads_sparse_coo_ref, grads_sparse_core, set_tier_override, sgld_apply_core,
    SimdTier,
};
use psgld::linalg::Mat;
use psgld::model::NmfModel;
use psgld::rng::Rng;
use psgld::samplers::{Dsgd, Psgld, Sampler};
use psgld::util::parallel::ScratchArena;

fn main() {
    header("Fig 5: sparse PSGLD vs DSGD per-iteration cost (K=50, B=15)");
    let k = 50usize;
    // --smoke: thin the workload so the CI trajectory run stays fast;
    // every JSON row is still produced, just on a sparser matrix.
    let density = if is_smoke() { 0.02 } else { 0.08 };
    let csr = movielens::movielens_like(density, k, 1);
    psgld::log_info!(
        "workload: {}x{} sparse, {} nnz\n",
        csr.rows(),
        csr.cols(),
        csr.nnz()
    );
    let mut json = JsonSink::at_repo_root("BENCH_fig5.json");
    let lam = (k as f64 / csr.mean()).sqrt() as f32;
    let model = NmfModel::poisson(k).with_priors(lam, lam);
    let run = RunConfig::quick(100)
        .with_step(StepSchedule::Polynomial { a: 1e-3, b: 0.51 });

    let grads_per_iter = csr.nnz() as f64 / 15.0;
    let mut p = Psgld::new_sparse(&csr, &model, 15, run.clone(), 2).unwrap();
    let mut t = 0u64;
    let s_p = time_it(3, 15, || {
        t += 1;
        p.step(t);
    });
    report("psgld (grads + noise + mirror)", s_p, Some((grads_per_iter, "grad-entries")));
    json.push("fig5/psgld_step", s_p, Some((grads_per_iter, "grad-entries")), 2);

    let mut d = Dsgd::new_sparse(&csr, &model, 15, run.clone(), 2).unwrap();
    let mut t = 0u64;
    let s_d = time_it(3, 15, || {
        t += 1;
        d.step(t);
    });
    report("dsgd (grads + mirror, no noise)", s_d, Some((grads_per_iter, "grad-entries")));
    json.push("fig5/dsgd_step", s_d, Some((grads_per_iter, "grad-entries")), 2);

    // isolate the noise cost: the only difference between the two
    let noise_entries = ((csr.rows() + csr.cols()) * k) as f64;
    let mut buf = vec![0.1f32; (csr.rows() + csr.cols()) * k];
    let zeros = vec![0f32; buf.len()];
    let mut rng = Rng::seed_from(3);
    let mut noise_scratch = ScratchArena::new();
    let s_n = time_it(3, 15, || {
        sgld_apply_core(&mut buf, &zeros, 0.01, 1.0, 0.0, true, &mut rng, &mut noise_scratch);
    });
    report("langevin noise alone ((I+J)K draws)", s_n, Some((noise_entries, "draws")));
    json.push("fig5/langevin_noise", s_n, Some((noise_entries, "draws")), 1);

    psgld::log_info!("");
    psgld::log_info!(
        "psgld/dsgd ratio {:.2}x; noise accounts for {:.0}% of the gap",
        s_p / s_d,
        100.0 * s_n / (s_p - s_d).max(1e-12)
    );
    psgld::log_info!(
        "(at the paper's full ML-10M scale the grad work grows 150x while the\n\
         noise only grows 12x, so the ratio approaches the paper's parity)"
    );

    // --- sparse block-gradient microbench: pre-PR COO scalar walk vs.
    // block-local CSR at the scalar and SIMD tiers (single-threaded).
    header("sparse block gradients: COO scalar (before) vs CSR+SIMD (after)");
    let bs = BlockedSparse::from_csr(&csr, 15).unwrap();
    let blk = bs.block(0, 0);
    let m = bs.grid().row_range(0).len();
    let n = bs.grid().col_range(0).len();
    let w = Mat::uniform(m, k, 0.1, 1.0, &mut rng);
    let ht = Mat::uniform(n, k, 0.1, 1.0, &mut rng);
    let mut gw = vec![0f32; m * k];
    let mut ght = vec![0f32; n * k];
    let nnz = blk.nnz() as f64;
    psgld::log_info!("block (0,0): {}x{} rows/cols, {} nnz, K={}", m, n, blk.nnz(), k);

    // the pre-PR layout: one (row, col, val) triple per entry
    let (mut rows, mut cols, mut vals) = (Vec::new(), Vec::new(), Vec::new());
    for (r, c, v) in blk.iter_coo() {
        rows.push(r);
        cols.push(c);
        vals.push(v);
    }

    let s_coo = time_it(3, 30, || {
        gw.fill(0.0);
        ght.fill(0.0);
        grads_sparse_coo_ref(
            w.as_slice(), ht.as_slice(), k, &rows, &cols, &vals, 1.0, 1.0, true,
            &mut gw, &mut ght,
        );
    });
    report("sparse_grads/before-coo-scalar", s_coo, Some((nnz, "nnz")));
    json.push("sparse_grads/before-coo-scalar", s_coo, Some((nnz, "nnz")), 1);

    set_tier_override(Some(SimdTier::Scalar));
    let s_csr_scalar = time_it(3, 30, || {
        gw.fill(0.0);
        ght.fill(0.0);
        grads_sparse_core(
            w.as_slice(), ht.as_slice(), k, blk, 1.0, 1.0, true, &mut gw, &mut ght,
        );
    });
    report("sparse_grads/after-csr-scalar", s_csr_scalar, Some((nnz, "nnz")));
    json.push("sparse_grads/after-csr-scalar", s_csr_scalar, Some((nnz, "nnz")), 1);

    set_tier_override(None);
    let tier = active_tier();
    let s_csr_simd = time_it(3, 30, || {
        gw.fill(0.0);
        ght.fill(0.0);
        grads_sparse_core(
            w.as_slice(), ht.as_slice(), k, blk, 1.0, 1.0, true, &mut gw, &mut ght,
        );
    });
    report("sparse_grads/after-csr-simd", s_csr_simd, Some((nnz, "nnz")));
    json.push("sparse_grads/after-csr-simd", s_csr_simd, Some((nnz, "nnz")), 1);

    let speedup = s_coo / s_csr_simd;
    psgld::log_info!("");
    psgld::log_info!(
        "active tier: {tier:?}; CSR layout alone {:.2}x, CSR+SIMD {speedup:.2}x \
         over the pre-PR scalar COO walk",
        s_coo / s_csr_scalar
    );
    // encoded so ops_per_s == the speedup ratio
    json.push("sparse_grads/coo_to_csr_simd_speedup", 1.0 / speedup, Some((1.0, "x")), 1);

    json.write();
    write_obs_summary("BENCH_fig5_obs.json");
}
