//! Bench for Fig. 5: sparse-matrix PSGLD vs DSGD per-iteration cost on
//! the MovieLens-like workload (K = 50, B = 15). The paper's claim is
//! runtime parity; the delta measured here is exactly the Langevin
//! noise generation, broken out separately.
//!
//! Run: `cargo bench --bench fig5_movielens`

mod bench_util;
use bench_util::{header, report, time_it};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::kernels::sgld_apply_core;
use psgld::model::NmfModel;
use psgld::rng::Rng;
use psgld::samplers::{Dsgd, Psgld, Sampler};

fn main() {
    header("Fig 5: sparse PSGLD vs DSGD per-iteration cost (K=50, B=15)");
    let k = 50usize;
    let csr = movielens::movielens_like(0.08, k, 1);
    println!(
        "workload: {}x{} sparse, {} nnz\n",
        csr.rows(),
        csr.cols(),
        csr.nnz()
    );
    let lam = (k as f64 / csr.mean()).sqrt() as f32;
    let model = NmfModel::poisson(k).with_priors(lam, lam);
    let run = RunConfig::quick(100)
        .with_step(StepSchedule::Polynomial { a: 1e-3, b: 0.51 });

    let grads_per_iter = csr.nnz() as f64 / 15.0;
    let mut p = Psgld::new_sparse(&csr, &model, 15, run.clone(), 2).unwrap();
    let mut t = 0u64;
    let s_p = time_it(3, 15, || {
        t += 1;
        p.step(t);
    });
    report("psgld (grads + noise + mirror)", s_p, Some((grads_per_iter, "grad-entries")));

    let mut d = Dsgd::new_sparse(&csr, &model, 15, run.clone(), 2).unwrap();
    let mut t = 0u64;
    let s_d = time_it(3, 15, || {
        t += 1;
        d.step(t);
    });
    report("dsgd (grads + mirror, no noise)", s_d, Some((grads_per_iter, "grad-entries")));

    // isolate the noise cost: the only difference between the two
    let noise_entries = ((csr.rows() + csr.cols()) * k) as f64;
    let mut buf = vec![0.1f32; (csr.rows() + csr.cols()) * k];
    let zeros = vec![0f32; buf.len()];
    let mut rng = Rng::seed_from(3);
    let s_n = time_it(3, 15, || {
        sgld_apply_core(&mut buf, &zeros, 0.01, 1.0, 0.0, true, &mut rng);
    });
    report("langevin noise alone ((I+J)K draws)", s_n, Some((noise_entries, "draws")));

    println!();
    println!(
        "psgld/dsgd ratio {:.2}x; noise accounts for {:.0}% of the gap",
        s_p / s_d,
        100.0 * s_n / (s_p - s_d).max(1e-12)
    );
    println!(
        "(at the paper's full ML-10M scale the grad work grows 150x while the\n\
         noise only grows 12x, so the ratio approaches the paper's parity)"
    );
}
