//! Bench for Fig. 2(a): per-iteration cost of every sampler on the
//! Poisson-NMF synthetic workload (I = J ∈ {256, 512}, K = 32,
//! B = I/32, |Ω| = IJ/32). The paper's wall-clock bars are the product
//! of these per-iteration times with T = 10 000.
//!
//! Run: `cargo bench --bench fig2a_poisson`

mod bench_util;
use bench_util::{header, report, time_it};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::model::NmfModel;
use psgld::samplers::{GibbsPoisson, Ld, Psgld, Sampler, Sgld};

fn main() {
    header("Fig 2(a): per-iteration sampler cost (Poisson-NMF, K=32)");
    for &i in &[256usize, 512] {
        let model = NmfModel::poisson(32);
        let data = synth::poisson_nmf(i, i, &model, 1);
        let n = (i * i) as f64;
        let run = RunConfig::quick(1_000)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

        let mut p = Psgld::new(&data.v, &model, i / 32, run.clone(), 2);
        let mut t = 0u64;
        let s = time_it(3, 10, || {
            t += 1;
            p.step(t);
        });
        report(&format!("psgld/I={i}"), s, Some((n / (i / 32) as f64, "entries")));

        let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 2e-5 }, 3);
        let mut t = 0u64;
        let s = time_it(1, 3, || {
            t += 1;
            ld.step(t);
        });
        report(&format!("ld/I={i}"), s, Some((n, "entries")));

        let mut sgld = Sgld::new(
            &data.v, &model, i * i / 32,
            StepSchedule::Polynomial { a: 1e-4, b: 0.51 }, 4,
        );
        let mut t = 0u64;
        let s = time_it(1, 5, || {
            t += 1;
            sgld.step(t);
        });
        report(&format!("sgld/I={i} (|O|=IJ/32)"), s, Some((n / 32.0, "entries")));

        let mut g = GibbsPoisson::new(&data.v, &model, 5);
        let mut t = 0u64;
        let s = time_it(0, 2, || {
            t += 1;
            g.step(t);
        });
        report(&format!("gibbs/I={i}"), s, Some((n, "entries")));
        psgld::log_info!("");
    }
    psgld::log_info!(
        "paper claim: PSGLD 700+x faster than Gibbs, 60+x faster than LD/SGLD per T iterations."
    );
}
