//! Tiny shared bench harness (criterion is unavailable offline):
//! warmup + repeated timing with mean / min / throughput reporting,
//! plus a machine-readable JSON sink (`BENCH_*.json` at the repo root).

// each bench compiles its own copy of this module and uses a subset
#![allow(dead_code)]

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// Time `f` over `reps` runs after `warmup` runs; returns seconds/run
/// (minimum over runs — least-noise estimator on a busy box). Under
/// `--smoke` the counts are scaled down via [`reps`], so every bench
/// supports the CI trajectory mode without per-site plumbing.
pub fn time_it(warmup: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    let (warmup, reps) = self::reps(warmup, reps);
    for _ in 0..warmup {
        f();
    }
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// `--smoke` on the bench command line: tiny rep counts for CI
/// trajectory runs (the numbers are noisier but the row set is
/// identical, which is all the regression gate needs).
pub fn is_smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Scale `(warmup, reps)` down when running with `--smoke`.
pub fn reps(warmup: usize, reps: usize) -> (usize, usize) {
    if is_smoke() {
        (1, reps.min(3))
    } else {
        (warmup, reps)
    }
}

/// Report one benchmark row.
pub fn report(name: &str, seconds: f64, work_items: Option<(f64, &str)>) {
    match work_items {
        Some((n, unit)) => psgld::log_info!(
            "{name:<44} {:>12}   {:>14}",
            fmt_s(seconds),
            format!("{:.2e} {unit}/s", n / seconds)
        ),
        None => psgld::log_info!("{name:<44} {:>12}", fmt_s(seconds)),
    }
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

pub fn header(title: &str) {
    psgld::log_info!("\n=== {title} ===");
    psgld::log_info!("{:<44} {:>12}   {:>14}", "benchmark", "time", "throughput");
    psgld::log_info!("{}", "-".repeat(76));
}

/// Collects benchmark rows and writes them as a JSON array (one object
/// per row: name, ns_per_iter, ops_per_s, unit, threads). Consumed by
/// EXPERIMENTS.md §Perf and any external tooling.
pub struct JsonSink {
    path: PathBuf,
    rows: Vec<String>,
}

impl JsonSink {
    /// Sink writing `file` at the repository root (one level above the
    /// crate manifest).
    pub fn at_repo_root(file: &str) -> Self {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(file);
        JsonSink { path, rows: Vec::new() }
    }

    /// Record one row. `ops` is `(work_items, unit)` as passed to
    /// [`report`]; `threads` is the worker count the row ran with
    /// (1 for single-threaded kernels).
    pub fn push(&mut self, name: &str, seconds: f64, ops: Option<(f64, &str)>, threads: usize) {
        let (ops_per_s, unit) = match ops {
            Some((n, unit)) => (n / seconds, unit),
            None => (1.0 / seconds, "iters"),
        };
        self.rows.push(format!(
            "{{\"name\":\"{}\",\"ns_per_iter\":{:.1},\"ops_per_s\":{:.2},\"unit\":\"{}\",\"threads\":{}}}",
            name.replace('"', "'"),
            seconds * 1e9,
            ops_per_s,
            unit,
            threads
        ));
    }

    /// Write the collected rows; failures are reported, not fatal
    /// (benches should still print their table on a read-only checkout).
    pub fn write(&self) {
        let body = format!("[\n  {}\n]\n", self.rows.join(",\n  "));
        match std::fs::File::create(&self.path).and_then(|mut f| f.write_all(body.as_bytes())) {
            Ok(()) => psgld::log_info!("\nwrote {}", self.path.display()),
            Err(e) => psgld::log_error!("\ncould not write {}: {e}", self.path.display()),
        }
    }
}

/// Write the per-run observability summary (phase totals + counters) to
/// `file` at the repo root — a no-op when `PALLAS_OBS` is off so bench
/// timings stay uninstrumented by default.
pub fn write_obs_summary(file: &str) {
    if psgld::obs::level() == psgld::obs::ObsLevel::Off {
        return;
    }
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(file);
    match psgld::obs::write_summary(&path) {
        Ok(()) => psgld::log_info!("wrote {}", path.display()),
        Err(e) => psgld::log_error!("could not write {}: {e}", path.display()),
    }
}
