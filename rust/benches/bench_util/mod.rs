//! Tiny shared bench harness (criterion is unavailable offline):
//! warmup + repeated timing with mean / min / throughput reporting.

use std::time::Instant;

/// Time `f` over `reps` runs after `warmup` runs; returns seconds/run
/// (minimum over runs — least-noise estimator on a busy box).
pub fn time_it(warmup: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Report one benchmark row.
pub fn report(name: &str, seconds: f64, work_items: Option<(f64, &str)>) {
    match work_items {
        Some((n, unit)) => println!(
            "{name:<44} {:>12}   {:>14}",
            fmt_s(seconds),
            format!("{:.2e} {unit}/s", n / seconds)
        ),
        None => println!("{name:<44} {:>12}", fmt_s(seconds)),
    }
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<44} {:>12}   {:>14}", "benchmark", "time", "throughput");
    println!("{}", "-".repeat(76));
}
