//! Bench for Fig. 6: the cluster-simulator sweeps themselves (strong +
//! weak scaling), printing the paper's series, plus the DSGLD
//! communication comparison. Also times the simulator so its own cost
//! is on record.
//!
//! Run: `cargo bench --bench fig6_scaling`

mod bench_util;
use bench_util::{header, report, time_it};

use psgld::cluster::{
    dsgld_distributed_timing, psgld_distributed_timing, ComputeModel, NetworkModel,
    TimingWorkload,
};

fn main() {
    header("Fig 6: simulated-cluster scaling sweeps");
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let wl = TimingWorkload::ml10m(50);

    println!("\nFig 6(a) strong scaling (100 samples, virtual seconds):");
    println!("  nodes   total      compute    comm");
    for &b in &[5usize, 15, 30, 45, 60, 75, 90, 105, 120] {
        let rep = psgld_distributed_timing(&wl, b, 100, &net, &compute);
        println!(
            "  {b:>5}   {:>8.3}s  {:>8.3}s  {:>8.3}s",
            rep.virtual_seconds, rep.compute_seconds, rep.comm_seconds
        );
    }

    println!("\nFig 6(b) weak scaling (T = 10, data x4 & nodes x2 per step):");
    println!("  nodes   nnz     total");
    for s in 0..4u32 {
        let w = wl.doubled(s);
        let rep = psgld_distributed_timing(&w, 15 << s, 10, &net, &compute);
        println!(
            "  {:>5}   {:>4.0}M   {:>8.3}s",
            15usize << s,
            w.nnz as f64 / 1e6,
            rep.virtual_seconds
        );
    }

    println!("\nDSGLD communication comparison (15 nodes, 100 iters):");
    let p = psgld_distributed_timing(&wl, 15, 100, &net, &compute);
    let d = dsgld_distributed_timing(&wl, 15, 44_444, 2, 100, &net, &compute);
    println!(
        "  psgld comm {:.3}s   dsgld comm {:.3}s   ratio {:.0}x",
        p.comm_seconds,
        d.comm_seconds,
        d.comm_seconds / p.comm_seconds
    );

    // cost of the simulator itself
    let s = time_it(3, 20, || {
        let _ = psgld_distributed_timing(&wl, 120, 100, &net, &compute);
    });
    report("\nsimulator sweep cost (one 100-iter point)", s, None);
}
