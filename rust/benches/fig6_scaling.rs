//! Bench for Fig. 6: the cluster-simulator sweeps themselves (strong +
//! weak scaling), printing the paper's series, plus the DSGLD
//! communication comparison and the shared-memory worker-pool
//! before/after (persistent pool vs the spawn-per-step regime it
//! replaced). Also times the simulator so its own cost is on record.
//!
//! Run: `cargo bench --bench fig6_scaling`

mod bench_util;
use bench_util::{header, report, time_it, JsonSink};

use psgld::cluster::{
    dsgld_distributed_timing, psgld_distributed_timing, ComputeModel, NetworkModel,
    TimingWorkload,
};
use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::model::NmfModel;
use psgld::samplers::{ExecMode, Psgld, Sampler};
use psgld::util::parallel::default_threads;

fn main() {
    let mut json = JsonSink::at_repo_root("BENCH_fig6.json");

    header("Fig 6: simulated-cluster scaling sweeps");
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    let wl = TimingWorkload::ml10m(50);

    psgld::log_info!("\nFig 6(a) strong scaling (100 samples, virtual seconds):");
    psgld::log_info!("  nodes   total      compute    comm");
    for &b in &[5usize, 15, 30, 45, 60, 75, 90, 105, 120] {
        let rep = psgld_distributed_timing(&wl, b, 100, &net, &compute);
        psgld::log_info!(
            "  {b:>5}   {:>8.3}s  {:>8.3}s  {:>8.3}s",
            rep.virtual_seconds, rep.compute_seconds, rep.comm_seconds
        );
        json.push(
            &format!("fig6a_strong/B={b}"),
            rep.virtual_seconds / 100.0,
            Some((1.0, "iters")),
            b,
        );
    }

    psgld::log_info!("\nFig 6(b) weak scaling (T = 10, data x4 & nodes x2 per step):");
    psgld::log_info!("  nodes   nnz     total");
    for s in 0..4u32 {
        let w = wl.doubled(s);
        let rep = psgld_distributed_timing(&w, 15 << s, 10, &net, &compute);
        psgld::log_info!(
            "  {:>5}   {:>4.0}M   {:>8.3}s",
            15usize << s,
            w.nnz as f64 / 1e6,
            rep.virtual_seconds
        );
        json.push(
            &format!("fig6b_weak/step={s}"),
            rep.virtual_seconds / 10.0,
            Some((1.0, "iters")),
            15usize << s,
        );
    }

    psgld::log_info!("\nDSGLD communication comparison (15 nodes, 100 iters):");
    let p = psgld_distributed_timing(&wl, 15, 100, &net, &compute);
    let d = dsgld_distributed_timing(&wl, 15, 44_444, 2, 100, &net, &compute);
    psgld::log_info!(
        "  psgld comm {:.3}s   dsgld comm {:.3}s   ratio {:.0}x",
        p.comm_seconds,
        d.comm_seconds,
        d.comm_seconds / p.comm_seconds
    );

    // --- shared-memory step throughput: persistent pool vs spawn-per-step
    // (the ISSUE acceptance point: >= 1.5x at B = 8, blocks <= 128x128)
    header("shared-memory PSGLD step throughput: pool vs spawn (B=8, 128x128, K=16)");
    let threads = default_threads().min(8);
    let model = NmfModel::poisson(16);
    let data = synth::poisson_nmf(128, 128, &model, 7);
    let run = RunConfig::quick(1_000_000)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });
    let mut results = Vec::new();
    for (label, mode) in [("pool", ExecMode::Pool), ("spawn", ExecMode::Spawn)] {
        let mut s = Psgld::new(&data.v, &model, 8, run.clone(), 11)
            .with_threads(threads)
            .with_exec_mode(mode);
        let mut t = 0u64;
        let secs = time_it(20, 200, || {
            t += 1;
            s.step(t);
        });
        report(
            &format!("psgld_step/{label} ({threads} threads)"),
            secs,
            Some((1.0, "steps")),
        );
        json.push(&format!("psgld_step/{label}"), secs, Some((1.0, "steps")), threads);
        results.push((label, secs));
    }
    let (pool_s, spawn_s) = (results[0].1, results[1].1);
    let ratio = spawn_s / pool_s;
    psgld::log_info!("persistent pool speedup over spawn-per-step: {ratio:.2}x");
    // encoded so ops_per_s == the speedup ratio
    json.push("psgld_step/pool_vs_spawn_ratio", 1.0 / ratio, Some((1.0, "x")), threads);

    // cost of the simulator itself
    let s = time_it(3, 20, || {
        let _ = psgld_distributed_timing(&wl, 120, 100, &net, &compute);
    });
    report("\nsimulator sweep cost (one 100-iter point)", s, None);
    json.push("simulator_sweep_cost", s, None, 1);

    json.write();
}
