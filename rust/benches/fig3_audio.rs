//! Bench for Fig. 3: the audio workload's per-iteration sampler costs
//! (256×256 spectrogram, K = 8, B = 8). The paper reports 3.5 s /
//! 81 s / 533 s for PSGLD / LD / Gibbs over 10k samples — i.e. ratios
//! of ~23x and ~150x, which these per-iteration numbers reproduce up to
//! hardware constants.
//!
//! Run: `cargo bench --bench fig3_audio`

mod bench_util;
use bench_util::{header, report, time_it};

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::audio;
use psgld::model::NmfModel;
use psgld::samplers::{GibbsPoisson, Ld, Psgld, Sampler};

fn main() {
    header("Fig 3: audio decomposition per-iteration cost (256x256, K=8)");
    let data = audio::piano_spectrogram(256, 256, 1);
    let model = NmfModel::poisson(8);
    let n = (256 * 256) as f64;

    let run = RunConfig::quick(100)
        .with_step(StepSchedule::Polynomial { a: 5e-4, b: 0.51 });
    let mut p = Psgld::new(&data.v, &model, 8, run.clone(), 2);
    let mut t = 0u64;
    let s_p = time_it(3, 20, || {
        t += 1;
        p.step(t);
    });
    report("psgld/B=8", s_p, Some((n / 8.0, "entries")));

    let mut ld = Ld::new(&data.v, &model, StepSchedule::Constant { eps: 1e-5 }, 3);
    let mut t = 0u64;
    let s_l = time_it(1, 5, || {
        t += 1;
        ld.step(t);
    });
    report("ld", s_l, Some((n, "entries")));

    let mut g = GibbsPoisson::new(&data.v, &model, 4);
    let mut t = 0u64;
    let s_g = time_it(0, 3, || {
        t += 1;
        g.step(t);
    });
    report("gibbs", s_g, Some((n, "entries")));

    psgld::log_info!("");
    psgld::log_info!("10k-sample projections:  psgld {:.1}s   ld {:.1}s   gibbs {:.1}s",
             s_p * 1e4, s_l * 1e4, s_g * 1e4);
    psgld::log_info!("ratios vs psgld:         ld {:.0}x   gibbs {:.0}x   (paper: 23x, 152x)",
             s_l / s_p, s_g / s_p);
}
