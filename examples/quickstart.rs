//! Quickstart: sample the posterior of a Poisson-NMF model with PSGLD.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small synthetic matrix from the generative model, runs
//! the shared-memory PSGLD sampler, and prints the mixing trace plus a
//! posterior summary — the smallest end-to-end use of the public API.

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::metrics::SummaryStats;
use psgld::model::NmfModel;
use psgld::samplers::{run_sampler, Psgld, Sampler};

fn main() -> psgld::Result<()> {
    // 1. Model: Poisson-NMF (beta = 1), rank K = 16, E(1) priors.
    let model = NmfModel::poisson(16);

    // 2. Data: 128x128 counts drawn from the generative model.
    let data = synth::poisson_nmf(128, 128, &model, 42);
    psgld::log_info!(
        "data: {}x{} Poisson counts, mean {:.2}",
        data.v.rows(),
        data.v.cols(),
        data.v.as_slice().iter().sum::<f32>() / data.n() as f32
    );

    // 3. Sampler: B = 4 grid, cyclic parts, eps_t = (0.002/t)^0.51.
    let run = RunConfig::quick(1_000)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
        .with_monitor_every(100);
    let mut sampler = Psgld::new(&data.v, &model, 4, run.clone(), 7);

    // 4. Run, monitoring the data log-likelihood.
    let res = run_sampler(&mut sampler, &run, |s| {
        model.loglik_dense(&s.w, &s.h(), &data.v)
    });
    for (it, ll) in res.trace.iters.iter().zip(&res.trace.values) {
        psgld::log_info!("  iter {it:>5}  loglik {ll:.4e}");
    }

    // 5. Posterior summary.
    let stats = SummaryStats::from_chain(&res.trace.values[res.trace.len() / 2..]);
    psgld::log_info!(
        "\nposterior loglik: mean {:.4e} ± {:.2e} (ESS {:.0} of {} kept samples)",
        stats.mean,
        stats.sd,
        stats.ess,
        res.posterior.count()
    );
    let w_mean = res.posterior.w_mean();
    psgld::log_info!(
        "posterior-mean dictionary: {}x{}, column mass {:.2}..{:.2}",
        w_mean.rows(),
        w_mean.cols(),
        (0..16)
            .map(|k| (0..128).map(|i| w_mean.get(i, k)).sum::<f32>())
            .fold(f32::INFINITY, f32::min),
        (0..16)
            .map(|k| (0..128).map(|i| w_mean.get(i, k)).sum::<f32>())
            .fold(0.0, f32::max),
    );
    psgld::log_info!("sampling took {:.2}s for 1000 iterations", res.sampling_seconds);
    let nonneg = sampler.state().w.as_slice().iter().all(|&x| x >= 0.0);
    psgld::log_info!("final state non-negative: {nonneg}");
    Ok(())
}
