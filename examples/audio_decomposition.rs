//! Audio decomposition (the paper's §4.2.2 workload): factorise a piano
//! spectrogram into spectral templates (W) and activations (H) with
//! PSGLD, compare the Monte Carlo-averaged dictionary against the
//! ground-truth note templates, and against the LD baseline.
//!
//! ```sh
//! cargo run --release --example audio_decomposition
//! ```

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::audio;
use psgld::model::NmfModel;
use psgld::samplers::{run_sampler, Ld, Psgld};

fn main() -> psgld::Result<()> {
    let (bins, frames, k, b) = (256, 256, 8, 8);
    let data = audio::piano_spectrogram(bins, frames, 2015);
    let w_true = data.w_true.as_ref().expect("synthetic data has templates");
    let model = NmfModel::poisson(k);
    psgld::log_info!("piano spectrogram: {bins} bins x {frames} frames, {k} notes");

    // --- PSGLD: B = 8 grid, 2000 samples, half burn-in ---------------
    let t = 2_000;
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 5e-4, b: 0.51 })
        .with_monitor_every(t / 10);
    let mut psgld_s = Psgld::new(&data.v, &model, b, run.clone(), 1);
    let res_p = run_sampler(&mut psgld_s, &run, |s| {
        model.loglik_dense(&s.w, &s.h(), &data.v)
    });
    let w_psgld = res_p.posterior.w_mean();
    let score_p = audio::dictionary_recovery_score(&w_psgld, w_true);

    // --- LD baseline ---------------------------------------------------
    let run_ld = RunConfig::quick(t)
        .with_step(StepSchedule::Constant { eps: 1e-5 })
        .with_monitor_every(t / 10);
    let mut ld = Ld::new(&data.v, &model, run_ld.step, 2);
    let res_l = run_sampler(&mut ld, &run_ld, |s| {
        model.loglik_dense(&s.w, &s.h(), &data.v)
    });
    let w_ld = res_l.posterior.w_mean();
    let score_l = audio::dictionary_recovery_score(&w_ld, w_true);

    psgld::log_info!("\n                 PSGLD        LD");
    psgld::log_info!(
        "time ({} it)   {:>8.2}s  {:>8.2}s",
        t, res_p.sampling_seconds, res_l.sampling_seconds
    );
    psgld::log_info!(
        "final loglik   {:>9.3e}  {:>9.3e}",
        res_p.trace.last_value(),
        res_l.trace.last_value()
    );
    psgld::log_info!(
        "recovery       {score_p:>9.3}  {score_l:>9.3}   (mean cosine vs true templates)"
    );
    psgld::log_info!(
        "speedup        PSGLD is {:.0}x faster than LD at the same sample count",
        res_l.sampling_seconds / res_p.sampling_seconds.max(1e-9)
    );

    // show where each learned template peaks (should sit near the true
    // fundamentals and their harmonics)
    psgld::log_info!("\nlearned template peaks (PSGLD):");
    for kk in 0..k {
        let (mut best_bin, mut best) = (0usize, 0f32);
        for i in 0..bins {
            if w_psgld.get(i, kk) > best {
                best = w_psgld.get(i, kk);
                best_bin = i;
            }
        }
        psgld::log_info!("  component {kk}: peak at bin {best_bin:>3} (mass {best:.2})");
    }
    Ok(())
}
