//! End-to-end distributed driver (the mandated e2e validation run):
//! Bayesian matrix factorisation of a MovieLens-scale sparse ratings
//! matrix on the simulated cluster — the full stack in one binary:
//!
//!   data generator -> B x B sparse partitioning -> distributed PSGLD
//!   (ring of Fig. 4, virtual-time cost model) -> RMSE curve + posterior
//!   summary, with the DSGD optimisation baseline side by side.
//!
//! ```sh
//! cargo run --release --example movielens_distributed [-- --scale 0.08]
//! ```
//!
//! The measured RMSE curve and timing land in EXPERIMENTS.md.

use psgld::cluster::{psgld_distributed_full, ComputeModel, NetworkModel};
use psgld::config::{RunConfig, StepSchedule};
use psgld::data::movielens;
use psgld::metrics::rmse_sparse;
use psgld::model::NmfModel;
use psgld::samplers::{run_sampler, Dsgd};

fn main() -> psgld::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.08);

    let (k, b, t) = (50usize, 15usize, 300u64);
    let csr = movielens::movielens_like(scale, k, 99);
    psgld::log_info!(
        "ratings matrix: {} movies x {} users, {} ratings ({:.2}% dense), mean {:.2}",
        csr.rows(),
        csr.cols(),
        csr.nnz(),
        100.0 * csr.nnz() as f64 / (csr.rows() as f64 * csr.cols() as f64),
        csr.mean()
    );

    // match the prior scale to the ratings: E[mu] = K/(lam^2) = mean(V)
    let lam = (k as f64 / csr.mean()).sqrt() as f32;
    let model = NmfModel::poisson(k).with_priors(lam, lam);
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 1e-3, b: 0.51 })
        .with_monitor_every(t / 15);

    // --- distributed PSGLD on the simulated 15-node cluster -----------
    let net = NetworkModel::paper_cluster();
    let compute = ComputeModel::paper_node();
    psgld::log_info!("\ndistributed PSGLD (B = {b} simulated nodes, ring H-rotation):");
    let rep = psgld_distributed_full(&csr, &model, b, &run, 7, &net, &compute, |s| {
        rmse_sparse(&s.w, &s.h(), &csr)
    })?;
    let trace = rep.trace.as_ref().expect("full fidelity");
    for (it, (sec, rmse)) in trace
        .iters
        .iter()
        .zip(trace.seconds.iter().zip(&trace.values))
    {
        psgld::log_info!("  iter {it:>4}  vclock {sec:>8.2}s  RMSE {rmse:.4}");
    }
    psgld::log_info!(
        "  virtual time {:.1}s = {:.1}s compute + {:.2}s communication",
        rep.virtual_seconds, rep.compute_seconds, rep.comm_seconds
    );

    // --- DSGD baseline (same partitioning, no Langevin noise) ---------
    psgld::log_info!("\nDSGD baseline (same grid, shared-memory):");
    let mut dsgd = Dsgd::new_sparse(&csr, &model, b, run.clone(), 7)?;
    let res = run_sampler(&mut dsgd, &run, |s| rmse_sparse(&s.w, &s.h(), &csr));
    psgld::log_info!(
        "  final RMSE {:.4} in {:.2}s wall",
        res.trace.last_value(),
        res.sampling_seconds
    );

    let final_psgld = trace.last_value();
    let final_dsgd = res.trace.last_value();
    psgld::log_info!(
        "\nheadline: PSGLD (a full Bayesian sampler) reaches RMSE {final_psgld:.4} vs \
         DSGD's {final_dsgd:.4};\nthe paper's point — the sampler is not \
         meaningfully slower than the optimiser — holds when the gap is small."
    );
    Ok(())
}
