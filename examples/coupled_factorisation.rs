//! Coupled matrix factorisation — the extension the paper's conclusion
//! proposes: two observed matrices share the dictionary W (e.g. the
//! same notes heard in two different recordings, or ratings + item
//! content). The coupled PSGLD sampler updates W from both likelihoods
//! while keeping the B-way block parallelism.
//!
//! ```sh
//! cargo run --release --example coupled_factorisation
//! ```
//!
//! Demonstrates the benefit: when V1 is scarce (few columns), coupling
//! to a richer V2 sharpens the dictionary and the V1 reconstruction.

use psgld::config::{RunConfig, StepSchedule};
use psgld::linalg::Mat;
use psgld::metrics::{gelman_rubin, rmse_dense};
use psgld::model::NmfModel;
use psgld::rng::{Dist, Rng};
use psgld::samplers::{CoupledPsgld, Psgld, Sampler};

fn main() -> psgld::Result<()> {
    let (i, j1, j2, k) = (48usize, 8usize, 96usize, 4usize);
    let mut rng = Rng::seed_from(11);
    let w_true = Mat::exponential(i, k, 1.0, &mut rng);
    let h1 = Mat::exponential(k, j1, 1.0, &mut rng);
    let h2 = Mat::exponential(k, j2, 1.0, &mut rng);
    let mu1 = w_true.matmul_abs(&h1)?;
    let mu2 = w_true.matmul_abs(&h2)?;
    let v1 = Mat::from_fn(i, j1, |r, c| rng.poisson(mu1.get(r, c) as f64) as f32);
    let v2 = Mat::from_fn(i, j2, |r, c| rng.poisson(mu2.get(r, c) as f64) as f32);
    psgld::log_info!(
        "shared dictionary, two observations: V1 {i}x{j1} (scarce), V2 {i}x{j2} (rich)"
    );

    let model = NmfModel::poisson(k);
    let t = 1_500u64;
    let run = RunConfig::quick(t)
        .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 });

    // --- coupled: W informed by both matrices --------------------------
    let mut coupled = CoupledPsgld::new(&v1, &v2, &model, 4, run.clone(), 3)?;
    for it in 1..=t {
        coupled.step(it);
    }
    let cs = coupled.coupled_state();
    let rec_coupled = rmse_dense(&cs.w, &cs.ht1.transpose(), &mu1);

    // --- solo: V1 only --------------------------------------------------
    let mut solo = Psgld::new(&v1, &model, 4, run.clone(), 3);
    for it in 1..=t {
        solo.step(it);
    }
    let rec_solo = rmse_dense(&solo.state().w, &solo.state().h(), &mu1);

    psgld::log_info!("\nreconstruction error of the noiseless mu1 (lower is better):");
    psgld::log_info!("  coupled (V1 + V2): {rec_coupled:.3}");
    psgld::log_info!("  solo (V1 only)   : {rec_solo:.3}");
    psgld::log_info!(
        "  coupling {}",
        if rec_coupled < rec_solo {
            "wins — the shared W borrows strength from V2"
        } else {
            "ties — V1 alone was already informative at this size"
        }
    );

    // --- multi-chain R-hat over the coupled sampler --------------------
    let chains: Vec<Vec<f64>> = (0..3)
        .map(|c| {
            let mut s = CoupledPsgld::new(&v1, &v2, &model, 4, run.clone(), 50 + c).unwrap();
            let mut vals = Vec::new();
            for it in 1..=t {
                s.step(it);
                if it > t / 2 && it % 5 == 0 {
                    let st = s.coupled_state();
                    vals.push(
                        st.w
                            .matmul_abs(&st.ht1.transpose())
                            .unwrap()
                            .as_slice()
                            .iter()
                            .map(|&x| x as f64)
                            .sum::<f64>(),
                    );
                }
            }
            vals
        })
        .collect();
    psgld::log_info!(
        "\nGelman-Rubin R-hat over 3 coupled chains: {:.3} (near 1 = converged)",
        gelman_rubin(&chains)
    );
    Ok(())
}
