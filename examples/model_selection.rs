//! Model selection — the application the paper's conclusion motivates:
//! with a posterior *sampler* (rather than a point optimiser) we can
//! compare model ranks K by held-out predictive performance averaged
//! over posterior samples.
//!
//! ```sh
//! cargo run --release --example model_selection
//! ```
//!
//! Data is generated at a known true rank; the posterior-averaged
//! held-out log-likelihood should peak near it, while training-set
//! likelihood alone keeps improving with K (the overfitting the
//! Bayesian average corrects).

use psgld::config::{RunConfig, StepSchedule};
use psgld::data::synth;
use psgld::linalg::Mat;
use psgld::model::{tweedie, NmfModel};
use psgld::rng::Rng;
use psgld::samplers::{run_sampler, Psgld, Sampler};

/// Split a dense matrix into train (value kept) / test (value hidden)
/// entries; hidden entries are replaced by the row-mean so the sampler
/// never sees them.
fn holdout_split(v: &Mat, frac: f64, seed: u64) -> (Mat, Vec<(usize, usize, f32)>) {
    let mut rng = Rng::derive(seed, &[0x9e1d]);
    let mut train = v.clone();
    let mut test = Vec::new();
    for i in 0..v.rows() {
        let row_mean =
            v.row(i).iter().sum::<f32>() / v.cols() as f32;
        for j in 0..v.cols() {
            if rng.next_f64() < frac {
                test.push((i, j, v.get(i, j)));
                train.set(i, j, row_mean.round());
            }
        }
    }
    (train, test)
}

fn main() -> psgld::Result<()> {
    let true_k = 8;
    let gen_model = NmfModel::poisson(true_k);
    let data = synth::poisson_nmf(96, 96, &gen_model, 7);
    let (train, test) = holdout_split(&data.v, 0.1, 8);
    psgld::log_info!(
        "true rank K* = {true_k}; {} held-out entries of {}",
        test.len(),
        data.n()
    );
    psgld::log_info!("\n  K   train loglik   held-out predictive loglik (posterior avg)");

    let mut best = (0usize, f64::NEG_INFINITY);
    for k in [2usize, 4, 8, 16, 24] {
        let model = NmfModel::poisson(k);
        let t = 600u64;
        let run = RunConfig::quick(t)
            .with_step(StepSchedule::Polynomial { a: 0.002, b: 0.51 })
            .with_monitor_every(t);
        let mut s = Psgld::new(&train, &model, 4, run.clone(), 10 + k as u64);

        // accumulate held-out predictive loglik over posterior samples
        let mut pred_sum = 0.0f64;
        let mut n_samples = 0u64;
        let res = run_sampler(&mut s, &run, |_| 0.0);
        let _ = res;
        // re-run collecting predictions every 25 post-burn-in iterations
        let mut s = Psgld::new(&train, &model, 4, run.clone(), 10 + k as u64);
        for it in 1..=t {
            s.step(it);
            if it > t / 2 && it % 25 == 0 {
                let state = s.state();
                let h = state.h();
                let mut ll = 0.0f64;
                for &(i, j, v) in &test {
                    let mut mu = tweedie::MU_EPS;
                    for kk in 0..k {
                        mu += state.w.get(i, kk).abs() * h.get(kk, j).abs();
                    }
                    ll += tweedie::loglik_entry(v, mu, 1.0, 1.0) as f64;
                }
                pred_sum += ll;
                n_samples += 1;
            }
        }
        let pred = pred_sum / n_samples as f64;
        let train_ll = model.loglik_dense(&s.state().w, &s.state().h(), &train);
        psgld::log_info!("  {k:<3} {train_ll:>13.4e}  {pred:>13.4e}");
        if pred > best.1 {
            best = (k, pred);
        }
    }
    psgld::log_info!(
        "\nselected rank K = {} (held-out predictive peak); true rank was {true_k}",
        best.0
    );
    Ok(())
}
